//! Implementation 2 — "C++ (CPU) + CUDA (GPU)": native host code driving
//! the **manual** driver API (the paper's Listing 2 flow): load module,
//! get function, alloc, upload, launch, download, free. No automation
//! layer; module handles are cached by hand exactly like the CUDA C
//! version keeps its `CUmodule` globals.

use std::collections::HashMap;

use crate::coordinator::{checked_cfg, checked_cfg2};
use crate::driver::{Context, Function, KernelArg, ModuleSource};
use crate::error::Result;
use crate::runtime::ArtifactLibrary;
use crate::tensor::Tensor;
use crate::tracetransform::functionals::{reduce_sinogram, FEATURE_COUNT, P_SET, T_SET};
use crate::tracetransform::image::Image;
use crate::tracetransform::impls::{
    alloc3, alloc_n, default_reduce, free3, free_n, DeviceChoice, ReduceMode, TraceImpl,
};

pub struct GpuManual {
    ctx: Context,
    device: DeviceChoice,
    library: Option<ArtifactLibrary>,
    /// Hand-managed function cache: (kernel, size, angles) -> handle.
    functions: HashMap<(String, usize, usize), Function>,
    /// Per-functional kernels instead of the fused `sinogram_all`
    /// (ablation; the paper's original 5-kernel structure).
    staged: bool,
}

impl GpuManual {
    pub fn new() -> Result<GpuManual> {
        Self::on_device(DeviceChoice::Pjrt)
    }

    pub fn on_device(device: DeviceChoice) -> Result<GpuManual> {
        let ctx = Context::create(&device.device()?)?;
        let library = match device {
            DeviceChoice::Pjrt => Some(ArtifactLibrary::load_default()?),
            DeviceChoice::Emulator => None,
        };
        Ok(GpuManual { ctx, device, library, functions: HashMap::new(), staged: false })
    }

    /// Use one kernel per T-functional (4 launches) instead of the fused
    /// multi-functional kernel — the §Perf "before" configuration.
    pub fn staged(mut self) -> Self {
        self.staged = true;
        self
    }

    pub fn context(&self) -> &Context {
        &self.ctx
    }

    fn function(&mut self, kernel: &str, s: usize, a: usize) -> Result<Function> {
        let key = (kernel.to_string(), s, a);
        if let Some(f) = self.functions.get(&key) {
            return Ok(f.clone());
        }
        let f = match self.device {
            DeviceChoice::Pjrt => {
                let lib = self.library.as_ref().expect("library loaded for pjrt");
                let sig = format!("f32[{s},{s}];f32[{a}]");
                let entry = lib.find(kernel, &sig)?.clone();
                let module = self.ctx.load_module(&lib.module_source(&entry))?;
                module.function("main")?
            }
            DeviceChoice::Emulator => {
                let vk = if kernel == "sinogram_all" {
                    crate::emulator::kernels::sinogram_all()?
                } else if kernel == "circus_all" {
                    crate::emulator::kernels::circus_all(s.next_power_of_two())?
                } else if kernel == "features_all" {
                    crate::emulator::kernels::features_all(a.next_power_of_two())?
                } else {
                    let tname = kernel.strip_prefix("sinogram_").unwrap_or(kernel);
                    crate::emulator::kernels::sinogram(tname)?
                };
                // resolve by the *generated* kernel's name — the width-
                // specialized reductions carry their tree width in it
                let fname = vk.name.clone();
                let module = self
                    .ctx
                    .load_module(&ModuleSource::Vtx { kernels: vec![vk] })?;
                module.function(&fname)?
            }
        };
        self.functions.insert(key, f.clone());
        Ok(f)
    }

    /// True when this call's P/F stage runs on the device: the
    /// `HLGPU_REDUCE` default on the emulator, fused structure only (the
    /// staged ablation keeps the paper's per-functional host reduce).
    fn device_reduce(&self) -> bool {
        self.device == DeviceChoice::Emulator
            && !self.staged
            && default_reduce() == ReduceMode::Device
    }
}

impl TraceImpl for GpuManual {
    fn name(&self) -> &'static str {
        "gpu-manual"
    }

    fn features(&mut self, img: &Image, thetas: &[f32]) -> Result<Vec<f32>> {
        // SLOC:core-begin
        let s = img.size();
        let a = thetas.len();
        let nt = T_SET.len();

        // manual memory management, Listing 2 style
        let img_t = img.to_tensor();
        let angles_t = Tensor::from_f32(thetas, &[a]);

        if self.device_reduce() {
            // Manual flavor of the device-resident chain: five buffers,
            // three launches, a FEATURE_COUNT-float download — the
            // sinograms never leave the device.
            let np = P_SET.len();
            let ptrs = alloc_n(
                &self.ctx,
                &[
                    img_t.byte_len(),
                    angles_t.byte_len(),
                    nt * a * s * 4,
                    nt * np * a * 4,
                    FEATURE_COUNT * 4,
                ],
            )?;
            let (ga, gb, gc, gd, ge) = (ptrs[0], ptrs[1], ptrs[2], ptrs[3], ptrs[4]);
            let body = (|| -> Result<Vec<f32>> {
                self.ctx.upload(ga, img_t.bytes())?;
                self.ctx.upload(gb, angles_t.bytes())?;
                let f = self.function("sinogram_all", s, a)?;
                f.launch(
                    &checked_cfg("sinogram_all", a, s)?,
                    &[
                        KernelArg::Ptr(ga),
                        KernelArg::Ptr(gb),
                        KernelArg::Ptr(gc),
                        KernelArg::I32(s as i32),
                    ],
                    self.ctx.memory()?,
                )?;
                let cf = self.function("circus_all", s, a)?;
                cf.launch(
                    &checked_cfg2("circus_all", (a, nt), s.next_power_of_two())?,
                    &[KernelArg::Ptr(gc), KernelArg::Ptr(gd), KernelArg::I32(s as i32)],
                    self.ctx.memory()?,
                )?;
                let ff = self.function("features_all", s, a)?;
                ff.launch(
                    &checked_cfg2("features_all", (np, nt), a.next_power_of_two())?,
                    &[KernelArg::Ptr(gd), KernelArg::Ptr(ge), KernelArg::I32(a as i32)],
                    self.ctx.memory()?,
                )?;
                let mut feats = Tensor::zeros_f32(&[FEATURE_COUNT]);
                self.ctx.download(ge, feats.bytes_mut())?;
                Ok(feats.to_vec_f32())
            })();
            return free_n(&self.ctx, &ptrs, body);
        }
        let out_elems = if self.staged { a * s } else { nt * a * s };
        let (ga, gb, gc) =
            alloc3(&self.ctx, img_t.byte_len(), angles_t.byte_len(), out_elems * 4)?;

        let scalar_args = |device: DeviceChoice| -> Vec<KernelArg> {
            let mut v = vec![KernelArg::Ptr(ga), KernelArg::Ptr(gb), KernelArg::Ptr(gc)];
            if device == DeviceChoice::Emulator {
                v.push(KernelArg::I32(s as i32));
            }
            v
        };

        // transfers + launches; buffers freed on every path below
        let body = (|| -> Result<Vec<f32>> {
            self.ctx.upload(ga, img_t.bytes())?;
            self.ctx.upload(gb, angles_t.bytes())?;
            let mut feats = Vec::with_capacity(nt * 6);
            if self.staged {
                // original structure: one kernel launch per T-functional
                let mut sino = Tensor::zeros_f32(&[a, s]);
                for t in T_SET {
                    let name = format!("sinogram_{}", t.name());
                    let f = self.function(&name, s, a)?;
                    f.launch(
                        &checked_cfg(&name, a, s)?,
                        &scalar_args(self.device),
                        self.ctx.memory()?,
                    )?;
                    self.ctx.download(gc, sino.bytes_mut())?;
                    feats.extend(reduce_sinogram(sino.as_f32(), a, s));
                }
            } else {
                // optimized: one fused launch computes all |T| sinograms
                let f = self.function("sinogram_all", s, a)?;
                f.launch(
                    &checked_cfg("sinogram_all", a, s)?,
                    &scalar_args(self.device),
                    self.ctx.memory()?,
                )?;
                let mut sinos = Tensor::zeros_f32(&[nt, a, s]);
                self.ctx.download(gc, sinos.bytes_mut())?;
                let all = sinos.as_f32();
                for ti in 0..nt {
                    feats.extend(reduce_sinogram(&all[ti * a * s..(ti + 1) * a * s], a, s));
                }
            }
            Ok(feats)
        })();

        // clean-up device memory (Listing 2 lines 29–32)
        let feats = free3(&self.ctx, ga, gb, gc, body)?;
        // SLOC:core-end
        Ok(feats)
    }
}

impl GpuManual {
    /// Diagnostic: how many modules this host code had to manage by hand.
    pub fn loaded_function_count(&self) -> usize {
        self.functions.len()
    }

    /// Validate artifact availability for a size before benchmarking.
    pub fn supports_size(&self, s: usize, a: usize) -> bool {
        match self.device {
            DeviceChoice::Emulator => true,
            DeviceChoice::Pjrt => {
                let sig = format!("f32[{s},{s}];f32[{a}]");
                self.library
                    .as_ref()
                    .map(|l| {
                        if self.staged {
                            T_SET.iter().all(|t| {
                                l.find(&format!("sinogram_{}", t.name()), &sig).is_ok()
                            })
                        } else {
                            l.find("sinogram_all", &sig).is_ok()
                        }
                    })
                    .unwrap_or(false)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::tracetransform::image::{orientations, shepp_logan};

    #[test]
    fn emulator_manual_runs_and_caches_functions() {
        let _g = crate::tracetransform::impls::REDUCE_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let img = shepp_logan(12);
        let thetas = orientations(6);
        let mut m = GpuManual::on_device(DeviceChoice::Emulator).unwrap();
        // fused kernel alone, or + the device P/F pair
        let expect = if m.device_reduce() { 3 } else { 1 };
        let f1 = m.features(&img, &thetas).unwrap();
        assert_eq!(m.loaded_function_count(), expect);
        let f2 = m.features(&img, &thetas).unwrap();
        assert_eq!(f1, f2);
        // device memory fully released after each call
        assert_eq!(m.context().memory().unwrap().live_buffers(), 0);
    }

    #[test]
    fn staged_and_fused_agree() {
        let img = shepp_logan(12);
        let thetas = orientations(6);
        let mut fused = GpuManual::on_device(DeviceChoice::Emulator).unwrap();
        let mut staged = GpuManual::on_device(DeviceChoice::Emulator).unwrap().staged();
        let a = fused.features(&img, &thetas).unwrap();
        let b = staged.features(&img, &thetas).unwrap();
        assert_eq!(staged.loaded_function_count(), T_SET.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!((x - y).abs() < 1e-3 * x.abs().max(1.0), "feature {i}: {x} vs {y}");
        }
    }

    #[test]
    fn missing_artifact_is_reported() {
        // 17x17 was never lowered; PJRT path must say NoArtifact
        if let Ok(mut m) = GpuManual::on_device(DeviceChoice::Pjrt) {
            assert!(!m.supports_size(17, 6));
            let img = shepp_logan(17);
            let err = m.features(&img, &orientations(6)).unwrap_err();
            assert!(
                matches!(err, Error::NoArtifact { .. }),
                "expected NoArtifact, got {err}"
            );
        }
    }
}
