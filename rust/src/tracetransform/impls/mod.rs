//! The five benchmark implementations of the trace transform (Tables 1–2,
//! Figure 3). Mapping to the paper's rows:
//!
//! | Paper | Here |
//! |---|---|
//! | C++ (CPU) | [`CpuNative`] — plain `f32` slices, fused sampling |
//! | C++ (CPU) + CUDA (GPU) | [`GpuManual`] — native host, manual driver API, AOT kernels |
//! | Julia (CPU) | [`CpuDynamic`] — boxed, bounds-checked `hostlang` arrays |
//! | Julia (CPU) + CUDA (GPU) | [`GpuDynamic`] — `hostlang` host code, manual driver API |
//! | Julia (CPU + GPU) | [`GpuAuto`] — full `@cuda` automation + specialization cache |
//!
//! All five produce the identical feature vector (order: (T, P, F)
//! lexicographic — `functionals::feature_order`), cross-checked in
//! `rust/tests/cross_check.rs`.

pub mod cpu_dynamic;
pub mod cpu_native;
pub mod gpu_auto;
pub mod gpu_dynamic;
pub mod gpu_manual;

pub use cpu_dynamic::CpuDynamic;
pub use cpu_native::CpuNative;
pub use gpu_auto::{AutoMode, GpuAuto};
pub use gpu_dynamic::GpuDynamic;
pub use gpu_manual::GpuManual;

use crate::driver::{Context, DevicePtr};
use crate::error::Result;
use crate::tracetransform::image::Image;

/// A trace-transform implementation under benchmark.
pub trait TraceImpl {
    /// Short name used in tables (matches the paper's row labels).
    fn name(&self) -> &'static str;

    /// Extract the full (T, P, F) feature vector.
    fn features(&mut self, img: &Image, thetas: &[f32]) -> Result<Vec<f32>>;

    /// Extract features for a whole batch of images against one angle
    /// set. The default loops [`TraceImpl::features`]; implementations
    /// with a cheaper batched path (one `batched_sinogram` launch, one
    /// angle-table upload, shared trig tables) override it — results
    /// must match the sequential path image for image.
    fn features_batch(&mut self, imgs: &[Image], thetas: &[f32]) -> Result<Vec<Vec<f32>>> {
        imgs.iter().map(|img| self.features(img, thetas)).collect()
    }
}

/// Where the P/F reduction stage of the trace pipeline runs (the
/// `HLGPU_REDUCE` knob).
///
/// * `Device` (the default): the sinograms never leave the device — the
///   `circus_all`/`features_all` kernels reduce them to the
///   `FEATURE_COUNT`-float feature block, and only that block is
///   downloaded (`|T|·a·s` floats of d2h traffic become 24 per image).
/// * `Host`: the pre-PR-5 behavior — download every sinogram and run
///   `functionals::reduce_sinogram` on the host. Kept as the
///   differential reference; CI runs tier-1 under both.
///
/// Only the VTX-emulator paths have the device lowering; PJRT and the
/// ablation modes always reduce on the host.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceMode {
    Host,
    Device,
}

impl ReduceMode {
    /// Parse an `HLGPU_REDUCE` value; unknown values select no mode.
    pub fn parse(v: &str) -> Option<ReduceMode> {
        match v.trim().to_ascii_lowercase().as_str() {
            "host" | "cpu" => Some(ReduceMode::Host),
            "device" | "gpu" => Some(ReduceMode::Device),
            _ => None,
        }
    }
}

/// Programmatic reduce-mode override (0 = unset, 1 = host, 2 = device).
/// Takes precedence over the environment, mirroring
/// [`crate::emulator::set_default_exec`].
static REDUCE_OVERRIDE: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Override the reduce stage's placement for subsequent calls
/// (process-wide). Pass `None` to clear. Benches and the differential
/// tests use this to A/B the two placements; both are observationally
/// identical (up to reduction-order rounding), so flipping it mid-run is
/// harmless for concurrent pipelines.
pub fn set_default_reduce(mode: Option<ReduceMode>) {
    REDUCE_OVERRIDE.store(
        match mode {
            None => 0,
            Some(ReduceMode::Host) => 1,
            Some(ReduceMode::Device) => 2,
        },
        std::sync::atomic::Ordering::Relaxed,
    );
}

/// The reduce placement used by pipelines that do not specify one: the
/// [`set_default_reduce`] override, else `HLGPU_REDUCE`, else the
/// device-resident stage.
pub fn default_reduce() -> ReduceMode {
    match REDUCE_OVERRIDE.load(std::sync::atomic::Ordering::Relaxed) {
        1 => return ReduceMode::Host,
        2 => return ReduceMode::Device,
        _ => {}
    }
    if let Ok(v) = std::env::var("HLGPU_REDUCE") {
        if let Some(m) = ReduceMode::parse(&v) {
            return m;
        }
    }
    ReduceMode::Device
}

/// Whether the batched trace pipeline shards a `features_batch` call
/// across the members of a [`DeviceSet`](crate::driver::DeviceSet) (the
/// `HLGPU_SHARD` knob).
///
/// * `Auto` (the default): when the pipeline holds more than one device
///   lane, the batch is split into contiguous chunks placed by
///   least-outstanding-work and executed concurrently, one thread per
///   lane; results are reassembled by image index and are bitwise
///   identical to the single-device path (each image's features depend
///   only on its own pixels).
/// * `Off`: always run the classic single-device double-buffered
///   pipeline on lane 0 — the differential reference, and what
///   count-asserting tests pin so per-context transfer counters stay
///   meaningful under `HLGPU_DEVICES>1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardMode {
    Auto,
    Off,
}

impl ShardMode {
    /// Parse an `HLGPU_SHARD` value; unknown values select no mode.
    pub fn parse(v: &str) -> Option<ShardMode> {
        match v.trim().to_ascii_lowercase().as_str() {
            "auto" | "on" => Some(ShardMode::Auto),
            "off" | "none" => Some(ShardMode::Off),
            _ => None,
        }
    }
}

/// Programmatic shard-mode override (0 = unset, 1 = auto, 2 = off),
/// mirroring [`set_default_reduce`].
static SHARD_OVERRIDE: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

/// Override the sharding policy for pipelines that do not specify one
/// (process-wide). Pass `None` to clear. Per-instance
/// [`GpuAuto::with_shard`] takes precedence over this.
pub fn set_default_shard(mode: Option<ShardMode>) {
    SHARD_OVERRIDE.store(
        match mode {
            None => 0,
            Some(ShardMode::Auto) => 1,
            Some(ShardMode::Off) => 2,
        },
        std::sync::atomic::Ordering::Relaxed,
    );
}

/// The sharding policy used by pipelines that do not specify one: the
/// [`set_default_shard`] override, else `HLGPU_SHARD`, else `Auto`.
pub fn default_shard() -> ShardMode {
    match SHARD_OVERRIDE.load(std::sync::atomic::Ordering::Relaxed) {
        1 => return ShardMode::Auto,
        2 => return ShardMode::Off,
        _ => {}
    }
    if let Ok(v) = std::env::var("HLGPU_SHARD") {
        if let Some(m) = ShardMode::parse(&v) {
            return m;
        }
    }
    ShardMode::Auto
}

/// Serializes tests that flip (or assert counts depending on) the
/// process-wide reduce-mode override — flipping is observationally
/// harmless for concurrent pipelines, but transfer/specialization
/// counters differ between the placements, so count-asserting tests
/// must not interleave with a flip.
#[cfg(test)]
pub(crate) static REDUCE_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Which device the GPU implementations run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceChoice {
    /// PJRT CPU client running AOT JAX/Pallas artifacts (device 0).
    Pjrt,
    /// VTX emulator (device 1) — no artifacts required.
    Emulator,
}

impl DeviceChoice {
    pub fn ordinal(self) -> usize {
        match self {
            DeviceChoice::Pjrt => 0,
            DeviceChoice::Emulator => 1,
        }
    }

    /// Resolve to a device through the named lookups — the table's
    /// ordinal layout is not part of the API contract.
    pub fn device(self) -> Result<crate::driver::Device> {
        match self {
            DeviceChoice::Pjrt => crate::driver::pjrt_device(),
            DeviceChoice::Emulator => crate::driver::emulator_device(),
        }
    }
}

/// Allocate one device buffer per requested byte length, freeing the
/// earlier ones when a later allocation fails — the manual paths must
/// not leak device memory on OOM.
pub(crate) fn alloc_n(ctx: &Context, sizes: &[usize]) -> Result<Vec<DevicePtr>> {
    let mut ptrs = Vec::with_capacity(sizes.len());
    for &bytes in sizes {
        match ctx.alloc(bytes) {
            Ok(p) => ptrs.push(p),
            Err(e) => {
                for p in ptrs {
                    let _ = ctx.free(p);
                }
                return Err(e);
            }
        }
    }
    Ok(ptrs)
}

/// Free every buffer unconditionally, then surface the body's result —
/// a body error wins over a free error, so a failed launch still
/// releases its buffers.
pub(crate) fn free_n<T>(ctx: &Context, ptrs: &[DevicePtr], body: Result<T>) -> Result<T> {
    let frees: Vec<Result<()>> = ptrs.iter().map(|&p| ctx.free(p)).collect();
    let v = body?;
    for f in frees {
        f?;
    }
    Ok(v)
}

/// The three buffers of a Listing-2-style call ([`alloc_n`] with the
/// historical arity).
pub(crate) fn alloc3(
    ctx: &Context,
    b0: usize,
    b1: usize,
    b2: usize,
) -> Result<(DevicePtr, DevicePtr, DevicePtr)> {
    let v = alloc_n(ctx, &[b0, b1, b2])?;
    Ok((v[0], v[1], v[2]))
}

/// [`free_n`] with the historical three-buffer arity.
pub(crate) fn free3<T>(
    ctx: &Context,
    p0: DevicePtr,
    p1: DevicePtr,
    p2: DevicePtr,
    body: Result<T>,
) -> Result<T> {
    free_n(ctx, &[p0, p1, p2], body)
}

/// Register the VTX providers for every `sinogram_<t>` logical kernel, so
/// the automation layer can serve the emulator device (the Ocelot path).
pub fn register_trace_providers(registry: &mut crate::coordinator::KernelRegistry) {
    use crate::coordinator::{checked_cfg, checked_cfg2, VtxSpec};
    use crate::driver::KernelArg;
    use crate::error::Error;

    for t in crate::tracetransform::functionals::T_SET {
        let name = format!("sinogram_{}", t.name());
        let tname = t.name();
        registry.register_vtx(&name, move |specs| {
            // specs: [img f32[s,s], angles f32[a], out f32[a,s]]
            if specs.len() != 3 || specs[0].shape.len() != 2 {
                return Err(Error::Specialize {
                    kernel: format!("sinogram_{tname}"),
                    reason: format!("unexpected argument shapes: {specs:?}"),
                });
            }
            let s = specs[0].shape[0];
            let a = specs[1].shape[0];
            Ok(VtxSpec {
                kernel: crate::emulator::kernels::sinogram(tname)?,
                scalars: vec![KernelArg::I32(s as i32)],
                config: checked_cfg(&format!("sinogram_{tname}"), a, s)?,
            })
        });
    }
    // the optimized fused variant: one pass, all four functionals
    registry.register_vtx("sinogram_all", |specs| {
        if specs.len() != 3 || specs[0].shape.len() != 2 {
            return Err(Error::Specialize {
                kernel: "sinogram_all".into(),
                reason: format!("unexpected argument shapes: {specs:?}"),
            });
        }
        let s = specs[0].shape[0];
        let a = specs[1].shape[0];
        Ok(VtxSpec {
            kernel: crate::emulator::kernels::sinogram_all()?,
            scalars: vec![KernelArg::I32(s as i32)],
            config: checked_cfg("sinogram_all", a, s)?,
        })
    });
    // the batched launch shape: N stacked images, one launch
    registry.register_vtx("batched_sinogram", |specs| {
        // specs: [imgs f32[n,s,s], angles f32[a], out f32[n,4,a,s]]
        if specs.len() != 3 || specs[0].shape.len() != 3 {
            return Err(Error::Specialize {
                kernel: "batched_sinogram".into(),
                reason: format!("unexpected argument shapes: {specs:?}"),
            });
        }
        let n = specs[0].shape[0];
        let s = specs[0].shape[1];
        let a = specs[1].shape[0];
        Ok(VtxSpec {
            kernel: crate::emulator::kernels::batched_sinogram()?,
            scalars: vec![KernelArg::I32(s as i32)],
            config: checked_cfg2("batched_sinogram", (a, n), s)?,
        })
    });
    // the device-side P stage: all |P| circus values per sinogram row
    // (input may be one image's [t,a,s] stack or a batch's [n,t,a,s] —
    // the kernel only sees rows, so the leading dims just multiply out)
    registry.register_vtx("circus_all", |specs| {
        // specs: [sinos f32[...,a,s], circus f32[...,|P|,a]]
        if specs.len() != 2 || specs[0].shape.len() < 3 {
            return Err(Error::Specialize {
                kernel: "circus_all".into(),
                reason: format!("unexpected argument shapes: {specs:?}"),
            });
        }
        let sh = &specs[0].shape;
        let s = sh[sh.len() - 1];
        let a = sh[sh.len() - 2];
        let rows: usize = sh[..sh.len() - 2].iter().product();
        let block_h = s.next_power_of_two();
        Ok(VtxSpec {
            kernel: crate::emulator::kernels::circus_all(block_h)?,
            scalars: vec![KernelArg::I32(s as i32)],
            config: checked_cfg2("circus_all", (a, rows), block_h)?,
        })
    });
    // the device-side F stage: mean + max over every circus function,
    // writing the (T, P, F)-ordered feature block
    registry.register_vtx("features_all", |specs| {
        // specs: [circus f32[...,|P|,a], out f32[...]]
        if specs.len() != 2 || specs[0].shape.len() < 2 {
            return Err(Error::Specialize {
                kernel: "features_all".into(),
                reason: format!("unexpected argument shapes: {specs:?}"),
            });
        }
        let sh = &specs[0].shape;
        let a = sh[sh.len() - 1];
        let np = sh[sh.len() - 2];
        let rows: usize = sh[..sh.len() - 2].iter().product();
        let block_h = a.next_power_of_two();
        Ok(VtxSpec {
            kernel: crate::emulator::kernels::features_all(block_h)?,
            scalars: vec![KernelArg::I32(a as i32)],
            config: checked_cfg2("features_all", (np, rows), block_h)?,
        })
    });
    // the running example, for completeness
    registry.register_vtx("vadd", |specs| {
        let n = specs[0].numel();
        Ok(VtxSpec {
            kernel: crate::emulator::kernels::vadd()?,
            scalars: vec![KernelArg::I32(n as i32)],
            config: checked_cfg("vadd", n.div_ceil(256), 256u32)?,
        })
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracetransform::functionals::FEATURE_COUNT;
    use crate::tracetransform::image::{orientations, random_phantom, shepp_logan};

    #[test]
    fn cpu_native_and_dynamic_agree() {
        let img = shepp_logan(24);
        let thetas = orientations(12);
        let mut native = CpuNative::new();
        let mut dynamic = CpuDynamic::new();
        let a = native.features(&img, &thetas).unwrap();
        let b = dynamic.features(&img, &thetas).unwrap();
        assert_eq!(a.len(), FEATURE_COUNT);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            let tol = 1e-3 * x.abs().max(1.0);
            assert!((x - y).abs() < tol, "feature {i}: {x} vs {y}");
        }
    }

    #[test]
    fn emulator_auto_agrees_with_cpu_native() {
        let img = shepp_logan(16);
        let thetas = orientations(8);
        let mut native = CpuNative::new();
        let mut auto = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        let a = native.features(&img, &thetas).unwrap();
        let b = auto.features(&img, &thetas).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            let tol = 2e-3 * x.abs().max(1.0);
            assert!((x - y).abs() < tol, "feature {i}: {x} vs {y}");
        }
    }

    #[test]
    fn emulator_manual_agrees_with_cpu_native() {
        let img = shepp_logan(16);
        let thetas = orientations(8);
        let mut native = CpuNative::new();
        let mut manual = GpuManual::on_device(DeviceChoice::Emulator).unwrap();
        let a = native.features(&img, &thetas).unwrap();
        let b = manual.features(&img, &thetas).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            let tol = 2e-3 * x.abs().max(1.0);
            assert!((x - y).abs() < tol, "feature {i}: {x} vs {y}");
        }
    }

    #[test]
    fn alloc3_and_free3_never_leak_on_errors() {
        let ctx = Context::create(&crate::driver::emulator_device().unwrap()).unwrap();
        // the third allocation can never fit: the first two must not leak
        let err = alloc3(&ctx, 16, 16, usize::MAX / 2).unwrap_err();
        assert_eq!(err.status(), "ERROR_OUT_OF_MEMORY");
        assert_eq!(ctx.memory().unwrap().live_buffers(), 0);

        // free3 releases the buffers even when the body failed
        let (a, b, c) = alloc3(&ctx, 8, 8, 8).unwrap();
        let body: Result<()> = Err(crate::error::Error::Other("launch trap".into()));
        assert!(free3(&ctx, a, b, c, body).is_err());
        assert_eq!(ctx.memory().unwrap().live_buffers(), 0);
    }

    /// Every implementation's batched path must agree with its own
    /// sequential path, image for image.
    #[test]
    fn features_batch_matches_sequential_everywhere() {
        let imgs: Vec<Image> = (0..3).map(|i| random_phantom(12, 40 + i as u64)).collect();
        let thetas = orientations(6);
        let mut impls: Vec<Box<dyn TraceImpl>> = vec![
            Box::new(CpuNative::new()),
            Box::new(CpuDynamic::new()),
            Box::new(GpuAuto::on_device(DeviceChoice::Emulator).unwrap()),
            Box::new(GpuDynamic::on_device(DeviceChoice::Emulator).unwrap()),
            Box::new(GpuManual::on_device(DeviceChoice::Emulator).unwrap()),
        ];
        for im in impls.iter_mut() {
            let name = im.name();
            let batch = im.features_batch(&imgs, &thetas).unwrap();
            assert_eq!(batch.len(), imgs.len(), "{name}");
            for (i, img) in imgs.iter().enumerate() {
                let seq = im.features(img, &thetas).unwrap();
                assert_eq!(batch[i].len(), FEATURE_COUNT, "{name} image {i}");
                for (j, (x, y)) in batch[i].iter().zip(&seq).enumerate() {
                    let tol = 1e-4 * x.abs().max(1.0);
                    assert!(
                        (x - y).abs() < tol,
                        "{name} image {i} feature {j}: batch {x} vs seq {y}"
                    );
                }
            }
        }
    }

    /// The acceptance criterion of the batched path: fewer H2D transfers
    /// *and bytes* than the sequential loop — the v2 pipeline uploads
    /// only the stacked image chunks (the angle table is device-resident
    /// across batches).
    #[test]
    fn batched_auto_uploads_less_than_sequential() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let thetas = orientations(6);
        let imgs: Vec<Image> = (0..4).map(|i| random_phantom(12, 50 + i as u64)).collect();
        // Pin sharding off: the counts below are per-context, and under
        // `HLGPU_DEVICES>1` + shard auto the batch would spread across
        // lanes whose contexts this test does not inspect.
        let mut auto = GpuAuto::on_device(DeviceChoice::Emulator)
            .unwrap()
            .with_shard(Some(ShardMode::Off));
        // warm both specializations so steady-state transfers compare
        auto.features(&imgs[0], &thetas).unwrap();
        auto.features_batch(&imgs, &thetas).unwrap();

        auto.launcher().context().memory().unwrap().reset_stats();
        for img in &imgs {
            auto.features(img, &thetas).unwrap();
        }
        let seq = auto.launcher().context().mem_stats().unwrap();

        auto.launcher().context().memory().unwrap().reset_stats();
        auto.features_batch(&imgs, &thetas).unwrap();
        let bat = auto.launcher().context().mem_stats().unwrap();

        assert_eq!(seq.h2d_count, 2 * imgs.len() as u64, "image + angles per call");
        assert_eq!(bat.h2d_count, 2, "one stacked upload per double-buffer chunk");
        assert!(bat.h2d_count < seq.h2d_count);
        assert!(
            bat.h2d_bytes < seq.h2d_bytes,
            "device-resident angles: {} must undercut {}",
            bat.h2d_bytes,
            seq.h2d_bytes
        );
        assert_eq!(bat.alloc_count, 0, "warm batch allocates nothing");
    }

    #[test]
    fn emulator_dynamic_agrees_with_cpu_native() {
        let img = shepp_logan(16);
        let thetas = orientations(8);
        let mut native = CpuNative::new();
        let mut dynamic = GpuDynamic::on_device(DeviceChoice::Emulator).unwrap();
        let a = native.features(&img, &thetas).unwrap();
        let b = dynamic.features(&img, &thetas).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            let tol = 2e-3 * x.abs().max(1.0);
            assert!((x - y).abs() < tol, "feature {i}: {x} vs {y}");
        }
    }
}
