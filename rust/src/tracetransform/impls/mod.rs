//! The five benchmark implementations of the trace transform (Tables 1–2,
//! Figure 3). Mapping to the paper's rows:
//!
//! | Paper | Here |
//! |---|---|
//! | C++ (CPU) | [`CpuNative`] — plain `f32` slices, fused sampling |
//! | C++ (CPU) + CUDA (GPU) | [`GpuManual`] — native host, manual driver API, AOT kernels |
//! | Julia (CPU) | [`CpuDynamic`] — boxed, bounds-checked `hostlang` arrays |
//! | Julia (CPU) + CUDA (GPU) | [`GpuDynamic`] — `hostlang` host code, manual driver API |
//! | Julia (CPU + GPU) | [`GpuAuto`] — full `@cuda` automation + specialization cache |
//!
//! All five produce the identical feature vector (order: (T, P, F)
//! lexicographic — `functionals::feature_order`), cross-checked in
//! `rust/tests/cross_check.rs`.

pub mod cpu_dynamic;
pub mod cpu_native;
pub mod gpu_auto;
pub mod gpu_dynamic;
pub mod gpu_manual;

pub use cpu_dynamic::CpuDynamic;
pub use cpu_native::CpuNative;
pub use gpu_auto::{AutoMode, GpuAuto};
pub use gpu_dynamic::GpuDynamic;
pub use gpu_manual::GpuManual;

use crate::error::Result;
use crate::tracetransform::image::Image;

/// A trace-transform implementation under benchmark.
pub trait TraceImpl {
    /// Short name used in tables (matches the paper's row labels).
    fn name(&self) -> &'static str;

    /// Extract the full (T, P, F) feature vector.
    fn features(&mut self, img: &Image, thetas: &[f32]) -> Result<Vec<f32>>;
}

/// Which device the GPU implementations run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceChoice {
    /// PJRT CPU client running AOT JAX/Pallas artifacts (device 0).
    Pjrt,
    /// VTX emulator (device 1) — no artifacts required.
    Emulator,
}

impl DeviceChoice {
    pub fn ordinal(self) -> usize {
        match self {
            DeviceChoice::Pjrt => 0,
            DeviceChoice::Emulator => 1,
        }
    }
}

/// Register the VTX providers for every `sinogram_<t>` logical kernel, so
/// the automation layer can serve the emulator device (the Ocelot path).
pub fn register_trace_providers(registry: &mut crate::coordinator::KernelRegistry) {
    use crate::coordinator::VtxSpec;
    use crate::driver::{KernelArg, LaunchConfig};
    use crate::error::Error;

    for t in crate::tracetransform::functionals::T_SET {
        let name = format!("sinogram_{}", t.name());
        let tname = t.name();
        registry.register_vtx(&name, move |specs| {
            // specs: [img f32[s,s], angles f32[a], out f32[a,s]]
            if specs.len() != 3 || specs[0].shape.len() != 2 {
                return Err(Error::Specialize {
                    kernel: format!("sinogram_{tname}"),
                    reason: format!("unexpected argument shapes: {specs:?}"),
                });
            }
            let s = specs[0].shape[0];
            let a = specs[1].shape[0];
            Ok(VtxSpec {
                kernel: crate::emulator::kernels::sinogram(tname)?,
                scalars: vec![KernelArg::I32(s as i32)],
                config: LaunchConfig::new(a as u32, s as u32),
            })
        });
    }
    // the optimized fused variant: one pass, all four functionals
    registry.register_vtx("sinogram_all", |specs| {
        if specs.len() != 3 || specs[0].shape.len() != 2 {
            return Err(Error::Specialize {
                kernel: "sinogram_all".into(),
                reason: format!("unexpected argument shapes: {specs:?}"),
            });
        }
        let s = specs[0].shape[0];
        let a = specs[1].shape[0];
        Ok(VtxSpec {
            kernel: crate::emulator::kernels::sinogram_all()?,
            scalars: vec![KernelArg::I32(s as i32)],
            config: LaunchConfig::new(a as u32, s as u32),
        })
    });
    // the running example, for completeness
    registry.register_vtx("vadd", |specs| {
        let n = specs[0].numel();
        Ok(VtxSpec {
            kernel: crate::emulator::kernels::vadd()?,
            scalars: vec![KernelArg::I32(n as i32)],
            config: LaunchConfig::new(((n as u32) + 255) / 256, 256u32),
        })
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracetransform::functionals::FEATURE_COUNT;
    use crate::tracetransform::image::{orientations, shepp_logan};

    #[test]
    fn cpu_native_and_dynamic_agree() {
        let img = shepp_logan(24);
        let thetas = orientations(12);
        let mut native = CpuNative::new();
        let mut dynamic = CpuDynamic::new();
        let a = native.features(&img, &thetas).unwrap();
        let b = dynamic.features(&img, &thetas).unwrap();
        assert_eq!(a.len(), FEATURE_COUNT);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            let tol = 1e-3 * x.abs().max(1.0);
            assert!((x - y).abs() < tol, "feature {i}: {x} vs {y}");
        }
    }

    #[test]
    fn emulator_auto_agrees_with_cpu_native() {
        let img = shepp_logan(16);
        let thetas = orientations(8);
        let mut native = CpuNative::new();
        let mut auto = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        let a = native.features(&img, &thetas).unwrap();
        let b = auto.features(&img, &thetas).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            let tol = 2e-3 * x.abs().max(1.0);
            assert!((x - y).abs() < tol, "feature {i}: {x} vs {y}");
        }
    }

    #[test]
    fn emulator_manual_agrees_with_cpu_native() {
        let img = shepp_logan(16);
        let thetas = orientations(8);
        let mut native = CpuNative::new();
        let mut manual = GpuManual::on_device(DeviceChoice::Emulator).unwrap();
        let a = native.features(&img, &thetas).unwrap();
        let b = manual.features(&img, &thetas).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            let tol = 2e-3 * x.abs().max(1.0);
            assert!((x - y).abs() < tol, "feature {i}: {x} vs {y}");
        }
    }

    #[test]
    fn emulator_dynamic_agrees_with_cpu_native() {
        let img = shepp_logan(16);
        let thetas = orientations(8);
        let mut native = CpuNative::new();
        let mut dynamic = GpuDynamic::on_device(DeviceChoice::Emulator).unwrap();
        let a = native.features(&img, &thetas).unwrap();
        let b = dynamic.features(&img, &thetas).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            let tol = 2e-3 * x.abs().max(1.0);
            assert!((x - y).abs() < tol, "feature {i}: {x} vs {y}");
        }
    }
}
