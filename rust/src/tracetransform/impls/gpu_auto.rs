//! Implementation 5 — "Julia (CPU + GPU)": the full framework. Kernels
//! are launched through the automation layer (`Launcher`, the `@cuda`
//! analog): arguments wrapped `CuIn`/`CuOut`, specialization cached per
//! signature, transfers minimized, module management invisible — the host
//! code shrinks to the paper's Listing 3.
//!
//! The batched path uses the **launch API v2** (see `docs/api.md`): the
//! angle table and the image/sinogram buffers are device-resident
//! (`arg::cu_dev` / `cu_dev_mut`), the `batched_sinogram` kernel is a
//! bound [`KernelHandle`] launched with zero cache traffic, and the batch
//! is split into two chunks whose uploads (on a leased upload stream,
//! allocating from its own pool arena) overlap the other chunk's compute
//! (on a second leased stream, fenced by events) — the double-buffered
//! pipeline. The stream pair is **leased per batch** from a
//! [`StreamPool`] rather than owned: a batch that fails no longer
//! poisons the pipeline forever, because the pool quarantines a stream
//! returned with a sticky error and reclaims it (drain + clear) before
//! the next batch leases it — the serve layer (`rust/src/serve`,
//! `docs/serving.md`) relies on this to run many tenants' batches
//! through one pipeline object.
//!
//! Under the default `HLGPU_REDUCE=device` placement the P/F stage runs
//! on the device too: `sinogram_all → circus_all → features_all` chain
//! entirely device-side and only the `FEATURE_COUNT`-float feature block
//! comes back — in the batched path as an async [`PendingDownload`]
//! enqueued behind the chunk's kernel chain, so the sinograms are never
//! downloaded at all. `HLGPU_REDUCE=host` keeps the pre-v2 host
//! reduction as the differential reference.

use std::collections::HashMap;

use crate::coordinator::{
    arg, checked_cfg, checked_cfg2, DeviceArray, KernelHandle, KernelRegistry, Launcher,
    PendingDownload,
};
use crate::driver::{BackendKind, Context, Event, LaunchConfig, StreamPool};
use crate::error::{Error, Result};
use crate::tensor::{Dtype, Tensor};
use crate::tracetransform::functionals::{reduce_sinogram, FEATURE_COUNT, P_SET, T_SET};
use crate::tracetransform::image::Image;
use crate::tracetransform::impls::{
    default_reduce, register_trace_providers, DeviceChoice, ReduceMode, TraceImpl,
};

/// Which kernel structure the automated path launches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutoMode {
    /// One fused `sinogram_all` launch per image (the optimized default).
    SinogramAll,
    /// One launch per T-functional (the paper's original 5-kernel
    /// structure; §Perf "before" configuration).
    PerFunctional,
    /// One `trace_full` launch: the whole pipeline, P/F included, on
    /// device (L2 composition; PJRT artifacts only).
    TraceFull,
}

/// Device-resident P/F reduction stage of one pipeline: bound handles
/// and intermediate buffers for the `circus_all → features_all` chain.
struct ReduceStage {
    circus_handle: KernelHandle,
    features_handle: KernelHandle,
    circus: DeviceArray,
    feats: DeviceArray,
}

/// One double-buffer slot of the batched pipeline: a bound kernel handle
/// plus device-resident image and sinogram buffers for a fixed chunk
/// length — and, on the device-reduce path, the chunk's [`ReduceStage`].
struct ChunkPipe {
    handle: KernelHandle,
    imgs: DeviceArray,
    sinos: DeviceArray,
    reduce: Option<ReduceStage>,
}

/// Persistent device buffers of the *single-image* device-reduce chain,
/// keyed by (size, angles).
struct ReduceBufs {
    sinos: DeviceArray,
    circus: DeviceArray,
    feats: DeviceArray,
}

type PipeKey = (usize, usize, usize, usize, bool);

/// Internal-state error for the warm path: a cache entry the preceding
/// code should have populated came back empty. Surfaced as an error so a
/// desynced cache fails the one call instead of panicking mid-serve.
fn state_desync(what: &str) -> Error {
    Error::InvalidLaunch(format!(
        "batched-pipeline state desynced: {what} missing for this call's shape"
    ))
}

/// Warm-path lookup of a double-buffer pipe; `Err`, not panic, on a
/// cache/shape mismatch.
fn pipe_entry<'m>(
    pipes: &'m mut HashMap<PipeKey, ChunkPipe>,
    key: &PipeKey,
) -> Result<&'m mut ChunkPipe> {
    pipes
        .get_mut(key)
        .ok_or_else(|| state_desync(&format!("double-buffer pipe {key:?}")))
}

/// Read-only flavor of [`pipe_entry`] for the join stage.
fn pipe_view<'m>(pipes: &'m HashMap<PipeKey, ChunkPipe>, key: &PipeKey) -> Result<&'m ChunkPipe> {
    pipes
        .get(key)
        .ok_or_else(|| state_desync(&format!("double-buffer pipe {key:?}")))
}

/// The device-resident angle table, or an error when it was never
/// uploaded (or was invalidated) for this call.
fn angle_entry(angles: &Option<(Vec<u32>, DeviceArray)>) -> Result<&DeviceArray> {
    angles
        .as_ref()
        .map(|(_, arr)| arr)
        .ok_or_else(|| state_desync("device-resident angle table"))
}

/// Warm-path lookup of the single-image device-reduce buffers.
fn reduce_entry<'m>(
    bufs: &'m mut HashMap<(usize, usize), ReduceBufs>,
    key: (usize, usize),
) -> Result<&'m mut ReduceBufs> {
    bufs.get_mut(&key)
        .ok_or_else(|| state_desync(&format!("device-reduce buffers for (s,a)={key:?}")))
}

pub struct GpuAuto {
    launcher: Launcher,
    mode: AutoMode,
    /// Device-resident angle table, uploaded once per distinct angle set
    /// and reused across every subsequent call (keyed by the raw bits).
    angles_dev: Option<(Vec<u32>, DeviceArray)>,
    /// Double-buffer pipeline state keyed by (chunk_len, size, angles,
    /// slot, device_reduce) — two slots so chunk i+1's upload overlaps
    /// chunk i's compute without aliasing buffers; the reduce placement
    /// is part of the key because the pipes it builds differ.
    pipes: HashMap<(usize, usize, usize, usize, bool), ChunkPipe>,
    /// Single-image device-reduce buffers, keyed by (size, angles).
    reduce_bufs: HashMap<(usize, usize), ReduceBufs>,
    /// Pool the batched path leases its (upload, compute) stream pair
    /// from, built on first use. Leasing instead of owning means a
    /// failed batch's sticky stream error is quarantined and reclaimed
    /// at lease return, never carried into the next batch.
    streams: Option<StreamPool>,
}

impl GpuAuto {
    pub fn new() -> Result<GpuAuto> {
        Self::on_device(DeviceChoice::Pjrt)
    }

    pub fn on_device(device: DeviceChoice) -> Result<GpuAuto> {
        let launcher = match device {
            DeviceChoice::Pjrt => Launcher::with_default_context()?,
            DeviceChoice::Emulator => {
                let mut l = Launcher::emulator()?;
                register_trace_providers(l.registry_mut());
                l
            }
        };
        Ok(GpuAuto {
            launcher,
            mode: AutoMode::SinogramAll,
            angles_dev: None,
            pipes: HashMap::new(),
            reduce_bufs: HashMap::new(),
            streams: None,
        })
    }

    pub fn with_mode(mut self, mode: AutoMode) -> Self {
        self.mode = mode;
        self
    }

    /// Single-launch variant using the AOT fused full-pipeline graph.
    pub fn fused() -> Result<GpuAuto> {
        let ctx = Context::default_device()?;
        let registry = KernelRegistry::with_default_library()?;
        Ok(GpuAuto {
            launcher: Launcher::new(ctx, registry),
            mode: AutoMode::TraceFull,
            angles_dev: None,
            pipes: HashMap::new(),
            reduce_bufs: HashMap::new(),
            streams: None,
        })
    }

    pub fn launcher(&self) -> &Launcher {
        &self.launcher
    }

    pub fn launcher_mut(&mut self) -> &mut Launcher {
        &mut self.launcher
    }

    /// The batched path's stream pool, once a batch has built it — the
    /// serve layer and benches read its lease/quarantine counters.
    pub fn stream_pool(&self) -> Option<&StreamPool> {
        self.streams.as_ref()
    }

    /// True when this call's P/F stage runs on the device: the default
    /// placement (`HLGPU_REDUCE`) on the emulator backend, fused
    /// single-launch mode excluded (only the VTX registry carries the
    /// `circus_all`/`features_all` lowerings).
    fn device_reduce(&self) -> bool {
        self.mode == AutoMode::SinogramAll
            && self.launcher.context().device().kind == BackendKind::VtxEmulator
            && default_reduce() == ReduceMode::Device
    }

    /// The device-resident angle table for `thetas`, uploading only when
    /// the set changes.
    fn angle_table(&mut self, thetas: &[f32]) -> Result<()> {
        let key: Vec<u32> = thetas.iter().map(|t| t.to_bits()).collect();
        let stale = match &self.angles_dev {
            Some((k, _)) => *k != key,
            None => true,
        };
        if stale {
            let t = Tensor::from_f32(thetas, &[thetas.len()]);
            let arr = DeviceArray::from_tensor(self.launcher.context(), &t)?;
            self.angles_dev = Some((key, arr));
        }
        Ok(())
    }
}

impl TraceImpl for GpuAuto {
    fn name(&self) -> &'static str {
        match self.mode {
            AutoMode::SinogramAll => "gpu-auto",
            AutoMode::PerFunctional => "gpu-auto-staged",
            AutoMode::TraceFull => "gpu-auto-fused",
        }
    }

    fn features(&mut self, img: &Image, thetas: &[f32]) -> Result<Vec<f32>> {
        // SLOC:core-begin
        let s = img.size();
        let a = thetas.len();
        let nt = T_SET.len();
        let img_t = img.to_tensor();
        let angles_t = Tensor::from_f32(thetas, &[a]);

        match self.mode {
            AutoMode::TraceFull => {
                // one launch of the L2-fused pipeline
                let mut out =
                    Tensor::zeros_f32(&[crate::tracetransform::functionals::FEATURE_COUNT]);
                self.launcher.launch(
                    "trace_full",
                    checked_cfg("trace_full", a, s)?,
                    &mut [arg::cu_in(&img_t), arg::cu_in(&angles_t), arg::cu_out(&mut out)],
                )?;
                Ok(out.to_vec_f32())
            }
            AutoMode::SinogramAll if self.device_reduce() => {
                // Fully resident chain: the sinograms and circus
                // functions never leave the device; the only d2h is the
                // FEATURE_COUNT-float block.
                let np = P_SET.len();
                if !self.reduce_bufs.contains_key(&(s, a)) {
                    let ctx = self.launcher.context().clone();
                    self.reduce_bufs.insert(
                        (s, a),
                        ReduceBufs {
                            sinos: DeviceArray::alloc(&ctx, Dtype::F32, &[nt, a, s])?,
                            circus: DeviceArray::alloc(&ctx, Dtype::F32, &[nt, np, a])?,
                            feats: DeviceArray::alloc(&ctx, Dtype::F32, &[FEATURE_COUNT])?,
                        },
                    );
                }
                let bufs = reduce_entry(&mut self.reduce_bufs, (s, a))?;
                self.launcher.launch(
                    "sinogram_all",
                    checked_cfg("sinogram_all", a, s)?,
                    &mut [
                        arg::cu_in(&img_t),
                        arg::cu_in(&angles_t),
                        arg::cu_dev_mut(&mut bufs.sinos),
                    ],
                )?;
                self.launcher.launch(
                    "circus_all",
                    checked_cfg("circus_all", a, s)?,
                    &mut [arg::cu_dev(&bufs.sinos), arg::cu_dev_mut(&mut bufs.circus)],
                )?;
                self.launcher.launch(
                    "features_all",
                    checked_cfg("features_all", np, a)?,
                    &mut [arg::cu_dev(&bufs.circus), arg::cu_dev_mut(&mut bufs.feats)],
                )?;
                Ok(bufs.feats.download()?.to_vec_f32())
            }
            AutoMode::SinogramAll => {
                // @cuda (a, s) sinogram_all(CuIn(img), CuIn(angles), CuOut(sinos))
                let mut sinos = Tensor::zeros_f32(&[nt, a, s]);
                self.launcher.launch(
                    "sinogram_all",
                    checked_cfg("sinogram_all", a, s)?,
                    &mut [arg::cu_in(&img_t), arg::cu_in(&angles_t), arg::cu_out(&mut sinos)],
                )?;
                let all = sinos.as_f32();
                let mut feats = Vec::with_capacity(nt * 6);
                for ti in 0..nt {
                    feats.extend(reduce_sinogram(&all[ti * a * s..(ti + 1) * a * s], a, s));
                }
                Ok(feats)
            }
            AutoMode::PerFunctional => {
                // the paper's structure: one kernel per T-functional,
                // @cuda (a, s) sinogram_t(CuIn(img), CuIn(angles), CuOut(sino))
                let mut feats = Vec::with_capacity(nt * 6);
                let mut sino = Tensor::zeros_f32(&[a, s]);
                for t in T_SET {
                    self.launcher.launch(
                        &format!("sinogram_{}", t.name()),
                        checked_cfg(&format!("sinogram_{}", t.name()), a, s)?,
                        &mut [
                            arg::cu_in(&img_t),
                            arg::cu_in(&angles_t),
                            arg::cu_out(&mut sino),
                        ],
                    )?;
                    feats.extend(reduce_sinogram(sino.as_f32(), a, s));
                }
                Ok(feats)
            }
        }
        // SLOC:core-end
    }

    /// Batched path, launch API v2: the batch splits into two chunks
    /// processed through a double-buffered two-stream pipeline. The
    /// angle table and all kernel buffers are device-resident — the only
    /// host↔device traffic at steady state is one stacked-image upload
    /// per chunk and one sinogram download per chunk; the
    /// `batched_sinogram` handle launches with zero specialization-cache
    /// traffic.
    fn features_batch(&mut self, imgs: &[Image], thetas: &[f32]) -> Result<Vec<Vec<f32>>> {
        if imgs.is_empty() {
            return Ok(Vec::new());
        }
        let batched_ok = self.mode == AutoMode::SinogramAll
            && self.launcher.context().device().kind == BackendKind::VtxEmulator
            && imgs.iter().all(|i| i.size() == imgs[0].size());
        if !batched_ok {
            // PJRT artifacts and the ablation modes have no batched
            // lowering — sequential fallback
            return imgs.iter().map(|img| self.features(img, thetas)).collect();
        }
        let s = imgs[0].size();
        let n = imgs.len();
        let a = thetas.len();
        let nt = T_SET.len();
        let np = P_SET.len();
        let dev_reduce = self.device_reduce();

        let ctx = self.launcher.context().clone();
        self.angle_table(thetas)?;

        // Lease this batch's (upload, compute) stream pair. The pool is
        // built lazily with capacity 2, so warm batches lease the same
        // two streams (and their pool arenas) every time; the leases
        // return when this call ends — through the pool's
        // quarantine-then-reclaim path if the batch left a sticky error
        // behind, so one failed batch cannot poison the next.
        let streams = self.streams.get_or_insert_with(|| StreamPool::new(2));
        let upload = streams.checkout();
        let compute = streams.checkout();

        // Two chunks double-buffer: chunk 1's upload overlaps chunk 0's
        // compute. A singleton batch degenerates to one chunk.
        let half = n.div_ceil(2);
        let mut bounds = vec![(0usize, half)];
        if half < n {
            bounds.push((half, n));
        }

        // Bind handles + allocate device buffers per (chunk shape, slot),
        // reused across batches. Image buffers live in the upload
        // stream's arena, sinograms in the compute stream's — concurrent
        // stages allocate and copy without sharing a pool lock. On the
        // device-reduce path each slot also carries its circus/feature
        // buffers and the bound P/F-stage handles.
        for (slot, &(lo, hi)) in bounds.iter().enumerate() {
            let len = hi - lo;
            let key = (len, s, a, slot, dev_reduce);
            if !self.pipes.contains_key(&key) {
                let up_arena = upload.arena_id();
                let co_arena = compute.arena_id();
                let imgs_dev = DeviceArray::alloc_in(&ctx, up_arena, Dtype::F32, &[len, s, s])?;
                let mut sinos_dev =
                    DeviceArray::alloc_in(&ctx, co_arena, Dtype::F32, &[len, nt, a, s])?;
                let angles_dev = angle_entry(&self.angles_dev)?;
                let handle = self.launcher.bind(
                    "batched_sinogram",
                    &[
                        arg::cu_dev(&imgs_dev),
                        arg::cu_dev(angles_dev),
                        arg::cu_dev_mut(&mut sinos_dev),
                    ],
                )?;
                let reduce = if dev_reduce {
                    let mut circus =
                        DeviceArray::alloc_in(&ctx, co_arena, Dtype::F32, &[len, nt, np, a])?;
                    let mut feats =
                        DeviceArray::alloc_in(&ctx, co_arena, Dtype::F32, &[len, FEATURE_COUNT])?;
                    let circus_handle = self.launcher.bind(
                        "circus_all",
                        &[arg::cu_dev(&sinos_dev), arg::cu_dev_mut(&mut circus)],
                    )?;
                    let features_handle = self.launcher.bind(
                        "features_all",
                        &[arg::cu_dev(&circus), arg::cu_dev_mut(&mut feats)],
                    )?;
                    Some(ReduceStage { circus_handle, features_handle, circus, feats })
                } else {
                    None
                };
                self.pipes.insert(
                    key,
                    ChunkPipe { handle, imgs: imgs_dev, sinos: sinos_dev, reduce },
                );
            }
        }

        // Stage 1+2: enqueue every chunk's upload (stream U) and kernel
        // chain (stream C, fenced on the upload's event) before joining
        // any — that is what overlaps the stages. On the device-reduce
        // path the chain is sinogram → circus → features → async feature
        // readback, all stream-ordered; the sinograms never cross to the
        // host.
        let mem = ctx.memory_arc()?;
        let cfg = LaunchConfig::new(1u32, 1u32); // VTX providers pick their own grids
        let mut sino_pendings = Vec::new();
        let mut feat_pendings: Vec<(usize, usize, PendingDownload<'_>)> = Vec::new();
        for (slot, &(lo, hi)) in bounds.iter().enumerate() {
            let len = hi - lo;
            let pipe = pipe_entry(&mut self.pipes, &(len, s, a, slot, dev_reduce))?;
            let mut bytes = Vec::with_capacity(len * s * s * 4);
            for img in &imgs[lo..hi] {
                for v in img.pixels() {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
            }
            upload.copy_h2d(mem.clone(), pipe.imgs.ptr(), bytes)?;
            let uploaded = Event::new();
            upload.record_event(&uploaded)?;
            compute.wait_event(&uploaded)?;
            let angles_dev = angle_entry(&self.angles_dev)?;
            let pending = pipe.handle.launch_on(
                &compute,
                checked_cfg2("batched_sinogram", (a, len), s)?,
                &mut [
                    arg::cu_dev(&pipe.imgs),
                    arg::cu_dev(angles_dev),
                    arg::cu_dev_mut(&mut pipe.sinos),
                ],
            )?;
            match pipe.reduce.as_mut() {
                Some(rs) => {
                    // Same stream: the chain is ordered after the
                    // sinogram kernel without host synchronization.
                    rs.circus_handle.launch_on(
                        &compute,
                        cfg,
                        &mut [arg::cu_dev(&pipe.sinos), arg::cu_dev_mut(&mut rs.circus)],
                    )?;
                    rs.features_handle.launch_on(
                        &compute,
                        cfg,
                        &mut [arg::cu_dev(&rs.circus), arg::cu_dev_mut(&mut rs.feats)],
                    )?;
                    let pd = rs.features_handle.download_on(&compute, &rs.feats)?;
                    feat_pendings.push((lo, hi, pd));
                }
                None => sino_pendings.push((slot, lo, hi, pending)),
            }
        }

        let mut out = vec![Vec::new(); n];
        if dev_reduce {
            // Stage 3, device reduce: join each chunk's feature readback
            // — FEATURE_COUNT floats per image, zero sinogram d2h.
            for (lo, hi, pd) in feat_pendings {
                let feats_host = pd.wait()?;
                let all = feats_host.as_f32();
                for (i, feats_slot) in out[lo..hi].iter_mut().enumerate() {
                    *feats_slot = all[i * FEATURE_COUNT..(i + 1) * FEATURE_COUNT].to_vec();
                }
            }
            return Ok(out);
        }

        // Stage 3, host reduce: join chunks in order, download each
        // chunk's sinograms once, and reduce on the host.
        for (slot, lo, hi, pending) in sino_pendings {
            pending.wait()?;
            let len = hi - lo;
            let pipe = pipe_view(&self.pipes, &(len, s, a, slot, dev_reduce))?;
            let sinos_host = pipe.sinos.download()?;
            let all = sinos_host.as_f32();
            for (i, feats_slot) in out[lo..hi].iter_mut().enumerate() {
                let mut feats = Vec::with_capacity(nt * 6);
                for ti in 0..nt {
                    let off = (i * nt + ti) * a * s;
                    feats.extend(reduce_sinogram(&all[off..off + a * s], a, s));
                }
                *feats_slot = feats;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracetransform::functionals::FEATURE_COUNT;
    use crate::tracetransform::image::{orientations, shepp_logan};

    use crate::tracetransform::impls::REDUCE_TEST_LOCK;

    #[test]
    fn batched_pipeline_specializes_once_per_chunk_shape() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let thetas = orientations(5);
        let imgs: Vec<_> = (0..3)
            .map(|i| crate::tracetransform::image::random_phantom(10, i as u64))
            .collect();
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        // 3 images split into chunks of 2 and 1 — two call shapes; the
        // device-reduce chain binds 3 kernels per shape, the host path 1
        let per_shape: u64 = if m.device_reduce() { 3 } else { 1 };
        let b1 = m.features_batch(&imgs, &thetas).unwrap();
        assert_eq!(m.launcher().metrics().cold_specializations, 2 * per_shape);
        let b2 = m.features_batch(&imgs, &thetas).unwrap();
        assert_eq!(b1, b2);
        assert_eq!(
            m.launcher().metrics().cold_specializations,
            2 * per_shape,
            "warm batch re-specializes nothing"
        );
        // a 2-image batch splits into two length-1 chunks — the length-1
        // shapes are already specialized, so binding the new slot's
        // handles hits the cache and re-specializes nothing
        m.features_batch(&imgs[..2], &thetas).unwrap();
        assert_eq!(m.launcher().metrics().cold_specializations, 2 * per_shape);
        // cache stats confirm the handles bypass the cache on the warm
        // path: only the bind() calls touched it
        let st = m.launcher().cache_stats();
        assert_eq!(st.misses, 2 * per_shape);
    }

    #[test]
    fn warm_batch_moves_only_images_and_results() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let thetas = orientations(5);
        let imgs: Vec<_> = (0..4)
            .map(|i| crate::tracetransform::image::random_phantom(10, 20 + i as u64))
            .collect();
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        m.features_batch(&imgs, &thetas).unwrap(); // cold: builds pipes
        m.launcher().context().memory().unwrap().reset_stats();
        m.features_batch(&imgs, &thetas).unwrap();
        let st = m.launcher().context().mem_stats().unwrap();
        assert_eq!(st.alloc_count, 0, "warm batch allocates nothing");
        assert_eq!(st.h2d_count, 2, "one stacked upload per chunk, no angle re-upload");
        assert_eq!(st.d2h_count, 2, "one result download per chunk");
        // the device-resident skips are visible in the launch metrics
        let lm = m.launcher().metrics();
        assert!(lm.skipped_h2d > 0);
        assert!(lm.skipped_d2h > 0);
    }

    /// PR-5 acceptance criterion: on the device-reduce path a warm
    /// batched run performs **zero sinogram d2h transfers** — the bytes
    /// downloaded per image are exactly the `FEATURE_COUNT`-float block,
    /// asserted through both `MemStats` and the `LaunchMetrics`
    /// deferred-readback counters.
    #[test]
    fn device_reduce_batch_downloads_only_feature_blocks() {
        use crate::tracetransform::impls::set_default_reduce;
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_default_reduce(Some(ReduceMode::Device));
        let thetas = orientations(6);
        let imgs: Vec<_> = (0..5)
            .map(|i| crate::tracetransform::image::random_phantom(12, 90 + i as u64))
            .collect();
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        m.features_batch(&imgs, &thetas).unwrap(); // cold
        m.launcher().context().memory().unwrap().reset_stats();
        let lm_before = m.launcher().metrics();
        m.features_batch(&imgs, &thetas).unwrap();
        let st = m.launcher().context().mem_stats().unwrap();
        assert_eq!(
            st.d2h_bytes,
            (imgs.len() * FEATURE_COUNT * 4) as u64,
            "per-image download bytes == FEATURE_COUNT * 4"
        );
        let lm = m.launcher().metrics();
        assert_eq!(lm.d2h_deferred - lm_before.d2h_deferred, 2, "one async readback per chunk");
        assert_eq!(
            lm.features_bytes - lm_before.features_bytes,
            (imgs.len() * FEATURE_COUNT * 4) as u64
        );
        set_default_reduce(None);
    }

    /// The two reduce placements are observationally identical (up to
    /// reduction-order rounding) through the same pipeline object.
    #[test]
    fn host_and_device_reduce_agree() {
        use crate::tracetransform::impls::set_default_reduce;
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let img = shepp_logan(14);
        let thetas = orientations(7);
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        set_default_reduce(Some(ReduceMode::Host));
        let host = m.features(&img, &thetas).unwrap();
        set_default_reduce(Some(ReduceMode::Device));
        let dev = m.features(&img, &thetas).unwrap();
        set_default_reduce(None);
        assert_eq!(host.len(), FEATURE_COUNT);
        for (i, (h, d)) in host.iter().zip(&dev).enumerate() {
            assert!((h - d).abs() < 1e-4 * h.abs().max(1.0), "feature {i}: {h} vs {d}");
        }
    }

    /// Satellite regression (PR-6): the warm-path cache lookups return a
    /// typed error on desynced internal state instead of panicking — a
    /// `features_batch` call that hits a missing pipe/angle-table/reduce
    /// buffer fails that one call, not the process.
    #[test]
    fn desynced_pipe_cache_errors_instead_of_panicking() {
        let mut pipes: HashMap<PipeKey, ChunkPipe> = HashMap::new();
        let err = pipe_entry(&mut pipes, &(2, 10, 5, 0, true)).unwrap_err();
        assert!(matches!(err, Error::InvalidLaunch(_)), "got {err}");
        assert!(err.to_string().contains("state desynced"), "{err}");
        let err = pipe_view(&pipes, &(2, 10, 5, 0, true)).unwrap_err();
        assert!(matches!(err, Error::InvalidLaunch(_)), "got {err}");
        let err = angle_entry(&None).unwrap_err();
        assert!(err.to_string().contains("angle table"), "{err}");
        let mut bufs: HashMap<(usize, usize), ReduceBufs> = HashMap::new();
        let err = reduce_entry(&mut bufs, (10, 5)).unwrap_err();
        assert!(matches!(err, Error::InvalidLaunch(_)), "got {err}");
    }

    /// Clearing every piece of cached pipeline state mid-life and
    /// rerunning rebuilds it and produces bitwise-identical features —
    /// the desync error above is about *partial* loss, full rebuild is
    /// always safe.
    #[test]
    fn pipe_cache_rebuild_after_clear_keeps_results_identical() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let thetas = orientations(6);
        let imgs: Vec<_> = (0..3)
            .map(|i| crate::tracetransform::image::random_phantom(11, 70 + i as u64))
            .collect();
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        let before = m.features_batch(&imgs, &thetas).unwrap();
        m.pipes.clear();
        m.angles_dev = None;
        m.reduce_bufs.clear();
        let after = m.features_batch(&imgs, &thetas).unwrap();
        assert_eq!(before, after, "rebuilt pipeline is bitwise-identical");
    }

    /// The batched path leases its stream pair from a pool instead of
    /// owning streams: two warm batches lease the same two streams (so
    /// their pool arenas — and the warm-path zero-alloc invariant — are
    /// stable) and return them clean.
    #[test]
    fn batched_pipeline_pools_its_streams() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let thetas = orientations(5);
        let imgs: Vec<_> = (0..4)
            .map(|i| crate::tracetransform::image::random_phantom(10, 50 + i as u64))
            .collect();
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        m.features_batch(&imgs, &thetas).unwrap();
        m.features_batch(&imgs, &thetas).unwrap();
        let pool = m.streams.as_ref().expect("pool built on first batch");
        let st = pool.stats();
        assert_eq!(st.created, 2, "pool creates exactly the double-buffer pair");
        assert_eq!(st.leases, 4, "two leases per batch");
        assert_eq!(st.quarantined, 0, "clean batches quarantine nothing");
        assert_eq!(pool.idle_count(), 2, "both streams returned after the batch");
    }

    #[test]
    fn emulator_auto_runs_and_caches() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let img = shepp_logan(12);
        let thetas = orientations(5);
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        // device reduce: sinogram_all + circus_all + features_all;
        // host reduce: the fused sinogram_all only
        let expect_cold = if m.device_reduce() { 3 } else { 1 };
        let f1 = m.features(&img, &thetas).unwrap();
        assert_eq!(f1.len(), FEATURE_COUNT);
        let cold = m.launcher().metrics().cold_specializations;
        assert_eq!(cold, expect_cold);
        // second call: fully warm
        let f2 = m.features(&img, &thetas).unwrap();
        assert_eq!(f1, f2);
        assert_eq!(m.launcher().metrics().cold_specializations, cold);
    }
}
