//! Implementation 5 — "Julia (CPU + GPU)": the full framework. Kernels
//! are launched through the automation layer (`Launcher`, the `@cuda`
//! analog): arguments wrapped `CuIn`/`CuOut`, specialization cached per
//! signature, transfers minimized, module management invisible — the host
//! code shrinks to the paper's Listing 3.
//!
//! The batched path uses the **launch API v2** (see `docs/api.md`): the
//! angle table and the image/sinogram buffers are device-resident
//! (`arg::cu_dev` / `cu_dev_mut`), the `batched_sinogram` kernel is a
//! bound [`KernelHandle`] launched with zero cache traffic, and the batch
//! is split into chunks whose uploads (on a leased upload stream,
//! allocating from its own pool arena) overlap the other chunk's compute
//! (on a second leased stream, fenced by events) — the double-buffered
//! pipeline. The stream pair is **leased per batch** from a
//! [`StreamPool`] rather than owned: a batch that fails no longer
//! poisons the pipeline forever, because the pool quarantines a stream
//! returned with a sticky error and reclaims it (drain + clear) before
//! the next batch leases it — the serve layer (`rust/src/serve`,
//! `docs/serving.md`) relies on this to run many tenants' batches
//! through one pipeline object.
//!
//! **Multi-device** (see `docs/devices.md`): a `GpuAuto` holds one
//! [`DeviceLane`] — launcher, pipe cache, stream pool — per member of an
//! optional [`DeviceSet`]. Under `HLGPU_SHARD=auto` (the default) a
//! `features_batch` call on a multi-lane pipeline splits into contiguous
//! chunks placed by least-outstanding-work and executed concurrently,
//! one thread per lane, each running the same double-buffered two-stream
//! pipeline it would run alone; the angle table is a
//! [`ReplicatedArray`], uploaded lazily once per member. Every image's
//! features depend only on its own pixels, so reassembling by image
//! index makes the sharded result **bitwise identical** to the
//! single-device path — `HLGPU_SHARD=off` pins everything to lane 0 and
//! is the differential reference.
//!
//! Under the default `HLGPU_REDUCE=device` placement the P/F stage runs
//! on the device too: `sinogram_all → circus_all → features_all` chain
//! entirely device-side and only the `FEATURE_COUNT`-float feature block
//! comes back — in the batched path as an async [`PendingDownload`]
//! enqueued behind the chunk's kernel chain, so the sinograms are never
//! downloaded at all. `HLGPU_REDUCE=host` keeps the pre-v2 host
//! reduction as the differential reference.

use std::collections::HashMap;

use crate::coordinator::{
    arg, checked_cfg, checked_cfg2, DeviceArray, KernelHandle, KernelRegistry, Launcher,
    PendingDownload, ReplicatedArray,
};
use crate::driver::{BackendKind, Context, DeviceSet, Event, LaunchConfig, StreamPool};
use crate::error::{Error, Result};
use crate::tensor::{Dtype, Tensor};
use crate::tracetransform::functionals::{reduce_sinogram, FEATURE_COUNT, P_SET, T_SET};
use crate::tracetransform::image::Image;
use crate::tracetransform::impls::{
    default_reduce, default_shard, register_trace_providers, DeviceChoice, ReduceMode, ShardMode,
    TraceImpl,
};

/// Which kernel structure the automated path launches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutoMode {
    /// One fused `sinogram_all` launch per image (the optimized default).
    SinogramAll,
    /// One launch per T-functional (the paper's original 5-kernel
    /// structure; §Perf "before" configuration).
    PerFunctional,
    /// One `trace_full` launch: the whole pipeline, P/F included, on
    /// device (L2 composition; PJRT artifacts only).
    TraceFull,
}

/// Device-resident P/F reduction stage of one pipeline: bound handles
/// and intermediate buffers for the `circus_all → features_all` chain.
struct ReduceStage {
    circus_handle: KernelHandle,
    features_handle: KernelHandle,
    circus: DeviceArray,
    feats: DeviceArray,
}

/// One double-buffer slot of the batched pipeline: a bound kernel handle
/// plus device-resident image and sinogram buffers for a fixed chunk
/// length — and, on the device-reduce path, the chunk's [`ReduceStage`].
struct ChunkPipe {
    handle: KernelHandle,
    imgs: DeviceArray,
    sinos: DeviceArray,
    reduce: Option<ReduceStage>,
}

/// Persistent device buffers of the *single-image* device-reduce chain,
/// keyed by (size, angles).
struct ReduceBufs {
    sinos: DeviceArray,
    circus: DeviceArray,
    feats: DeviceArray,
}

type PipeKey = (usize, usize, usize, usize, bool);

/// Internal-state error for the warm path: a cache entry the preceding
/// code should have populated came back empty. Surfaced as an error so a
/// desynced cache fails the one call instead of panicking mid-serve.
fn state_desync(what: &str) -> Error {
    Error::InvalidLaunch(format!(
        "batched-pipeline state desynced: {what} missing for this call's shape"
    ))
}

/// Warm-path lookup of a double-buffer pipe; `Err`, not panic, on a
/// cache/shape mismatch.
fn pipe_entry<'m>(
    pipes: &'m mut HashMap<PipeKey, ChunkPipe>,
    key: &PipeKey,
) -> Result<&'m mut ChunkPipe> {
    pipes
        .get_mut(key)
        .ok_or_else(|| state_desync(&format!("double-buffer pipe {key:?}")))
}

/// Read-only flavor of [`pipe_entry`] for the join stage.
fn pipe_view<'m>(pipes: &'m HashMap<PipeKey, ChunkPipe>, key: &PipeKey) -> Result<&'m ChunkPipe> {
    pipes
        .get(key)
        .ok_or_else(|| state_desync(&format!("double-buffer pipe {key:?}")))
}

/// The replicated angle table, or an error when it was never built (or
/// was invalidated) for this call.
fn angle_entry(angles: &Option<(Vec<u32>, ReplicatedArray)>) -> Result<&ReplicatedArray> {
    angles
        .as_ref()
        .map(|(_, rep)| rep)
        .ok_or_else(|| state_desync("device-resident angle table"))
}

/// Warm-path lookup of the single-image device-reduce buffers.
fn reduce_entry<'m>(
    bufs: &'m mut HashMap<(usize, usize), ReduceBufs>,
    key: (usize, usize),
) -> Result<&'m mut ReduceBufs> {
    bufs.get_mut(&key)
        .ok_or_else(|| state_desync(&format!("device-reduce buffers for (s,a)={key:?}")))
}

/// One device's worth of pipeline state: a launcher over that device's
/// context plus every per-context cache the batched path keeps warm. A
/// single-device `GpuAuto` is exactly one lane; a sharded one holds one
/// lane per [`DeviceSet`] member, and each lane's `run_chunks` is the
/// same double-buffered two-stream pipeline the single-device path runs.
struct DeviceLane {
    launcher: Launcher,
    /// Double-buffer pipeline state keyed by (chunk_len, size, angles,
    /// slot, device_reduce) — distinct slots so chunk i+1's upload
    /// overlaps chunk i's compute without aliasing buffers; the reduce
    /// placement is part of the key because the pipes it builds differ.
    pipes: HashMap<PipeKey, ChunkPipe>,
    /// Single-image device-reduce buffers, keyed by (size, angles).
    reduce_bufs: HashMap<(usize, usize), ReduceBufs>,
    /// Pool the batched path leases its (upload, compute) stream pair
    /// from, built on first use. Leasing instead of owning means a
    /// failed batch's sticky stream error is quarantined and reclaimed
    /// at lease return, never carried into the next batch.
    streams: Option<StreamPool>,
}

impl DeviceLane {
    /// A lane over an existing context: VTX contexts get an empty
    /// registry with the trace providers registered, anything else gets
    /// the default AOT artifact library.
    fn on_context(ctx: Context) -> Result<DeviceLane> {
        let launcher = match ctx.device().kind {
            BackendKind::VtxEmulator => {
                let mut l = Launcher::new(ctx, KernelRegistry::new(None));
                register_trace_providers(l.registry_mut());
                l
            }
            BackendKind::Pjrt => Launcher::new(ctx, KernelRegistry::with_default_library()?),
        };
        Ok(DeviceLane {
            launcher,
            pipes: HashMap::new(),
            reduce_bufs: HashMap::new(),
            streams: None,
        })
    }

    fn from_launcher(launcher: Launcher) -> DeviceLane {
        DeviceLane {
            launcher,
            pipes: HashMap::new(),
            reduce_bufs: HashMap::new(),
            streams: None,
        }
    }

    /// Drop every piece of warm cached state: double-buffer pipes,
    /// reduce buffers, the leased-stream pool. A lane whose batch just
    /// failed may hold buffers desynced from the kernel chain's
    /// progress — rebuilding them lazily on the next call is always
    /// safe (cold and warm paths are bitwise identical), whereas
    /// keeping them risks `InvalidLaunch` on a later, healthy call.
    fn invalidate(&mut self) {
        self.pipes.clear();
        self.reduce_bufs.clear();
        self.streams = None;
    }

    /// Run `chunks` — disjoint `(lo, hi)` index ranges into `imgs`, all
    /// of one image size — through this lane's double-buffered
    /// two-stream pipeline, writing image `i`'s feature vector into
    /// `out[i]`. This is the whole batched pipeline for one device; the
    /// single-device path calls it once with the classic two-chunk
    /// split, the sharded path calls it concurrently on every lane with
    /// that lane's placed chunks.
    fn run_chunks(
        &mut self,
        imgs: &[Image],
        chunks: &[(usize, usize)],
        angles: &ReplicatedArray,
        dev_reduce: bool,
        out: &mut [Vec<f32>],
    ) -> Result<()> {
        let s = imgs[0].size();
        let a = angles.master().shape()[0];
        let nt = T_SET.len();
        let np = P_SET.len();
        let ctx = self.launcher.context().clone();
        // This lane's replica of the angle table — uploaded on the first
        // batch this member sees, resident afterwards.
        let angles_dev = angles.on(&ctx)?;

        // Lease this batch's (upload, compute) stream pair. The pool is
        // built lazily with capacity 2, so warm batches lease the same
        // two streams (and their pool arenas) every time; the leases
        // return when this call ends — through the pool's
        // quarantine-then-reclaim path if the batch left a sticky error
        // behind, so one failed batch cannot poison the next.
        let streams = self.streams.get_or_insert_with(|| StreamPool::new(2));
        let upload = streams.checkout();
        let compute = streams.checkout();

        // Bind handles + allocate device buffers per (chunk shape, slot),
        // reused across batches. Image buffers live in the upload
        // stream's arena, sinograms in the compute stream's — concurrent
        // stages allocate and copy without sharing a pool lock. On the
        // device-reduce path each slot also carries its circus/feature
        // buffers and the bound P/F-stage handles.
        for (slot, &(lo, hi)) in chunks.iter().enumerate() {
            let len = hi - lo;
            let key = (len, s, a, slot, dev_reduce);
            if !self.pipes.contains_key(&key) {
                let up_arena = upload.arena_id();
                let co_arena = compute.arena_id();
                let imgs_dev = DeviceArray::alloc_in(&ctx, up_arena, Dtype::F32, &[len, s, s])?;
                let mut sinos_dev =
                    DeviceArray::alloc_in(&ctx, co_arena, Dtype::F32, &[len, nt, a, s])?;
                let handle = self.launcher.bind(
                    "batched_sinogram",
                    &[
                        arg::cu_dev(&imgs_dev),
                        arg::cu_dev(&angles_dev),
                        arg::cu_dev_mut(&mut sinos_dev),
                    ],
                )?;
                let reduce = if dev_reduce {
                    let mut circus =
                        DeviceArray::alloc_in(&ctx, co_arena, Dtype::F32, &[len, nt, np, a])?;
                    let mut feats =
                        DeviceArray::alloc_in(&ctx, co_arena, Dtype::F32, &[len, FEATURE_COUNT])?;
                    let circus_handle = self.launcher.bind(
                        "circus_all",
                        &[arg::cu_dev(&sinos_dev), arg::cu_dev_mut(&mut circus)],
                    )?;
                    let features_handle = self.launcher.bind(
                        "features_all",
                        &[arg::cu_dev(&circus), arg::cu_dev_mut(&mut feats)],
                    )?;
                    Some(ReduceStage { circus_handle, features_handle, circus, feats })
                } else {
                    None
                };
                self.pipes.insert(
                    key,
                    ChunkPipe { handle, imgs: imgs_dev, sinos: sinos_dev, reduce },
                );
            }
        }

        // Stage 1+2: enqueue every chunk's upload (stream U) and kernel
        // chain (stream C, fenced on the upload's event) before joining
        // any — that is what overlaps the stages. On the device-reduce
        // path the chain is sinogram → circus → features → async feature
        // readback, all stream-ordered; the sinograms never cross to the
        // host.
        let mem = ctx.memory_arc()?;
        let cfg = LaunchConfig::new(1u32, 1u32); // VTX providers pick their own grids
        let mut sino_pendings = Vec::new();
        let mut feat_pendings: Vec<(usize, usize, PendingDownload<'_>)> = Vec::new();
        for (slot, &(lo, hi)) in chunks.iter().enumerate() {
            let len = hi - lo;
            let pipe = pipe_entry(&mut self.pipes, &(len, s, a, slot, dev_reduce))?;
            let mut bytes = Vec::with_capacity(len * s * s * 4);
            for img in &imgs[lo..hi] {
                for v in img.pixels() {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
            }
            upload.copy_h2d(mem.clone(), pipe.imgs.ptr(), bytes)?;
            let uploaded = Event::new();
            upload.record_event(&uploaded)?;
            compute.wait_event(&uploaded)?;
            let pending = pipe.handle.launch_on(
                &compute,
                checked_cfg2("batched_sinogram", (a, len), s)?,
                &mut [
                    arg::cu_dev(&pipe.imgs),
                    arg::cu_dev(&angles_dev),
                    arg::cu_dev_mut(&mut pipe.sinos),
                ],
            )?;
            match pipe.reduce.as_mut() {
                Some(rs) => {
                    // Same stream: the chain is ordered after the
                    // sinogram kernel without host synchronization.
                    rs.circus_handle.launch_on(
                        &compute,
                        cfg,
                        &mut [arg::cu_dev(&pipe.sinos), arg::cu_dev_mut(&mut rs.circus)],
                    )?;
                    rs.features_handle.launch_on(
                        &compute,
                        cfg,
                        &mut [arg::cu_dev(&rs.circus), arg::cu_dev_mut(&mut rs.feats)],
                    )?;
                    let pd = rs.features_handle.download_on(&compute, &rs.feats)?;
                    feat_pendings.push((lo, hi, pd));
                }
                None => sino_pendings.push((slot, lo, hi, pending)),
            }
        }

        if dev_reduce {
            // Stage 3, device reduce: join each chunk's feature readback
            // — FEATURE_COUNT floats per image, zero sinogram d2h.
            for (lo, hi, pd) in feat_pendings {
                let feats_host = pd.wait()?;
                let all = feats_host.as_f32();
                for (i, feats_slot) in out[lo..hi].iter_mut().enumerate() {
                    *feats_slot = all[i * FEATURE_COUNT..(i + 1) * FEATURE_COUNT].to_vec();
                }
            }
            return Ok(());
        }

        // Stage 3, host reduce: join chunks in order, download each
        // chunk's sinograms once, and reduce on the host.
        for (slot, lo, hi, pending) in sino_pendings {
            pending.wait()?;
            let len = hi - lo;
            let pipe = pipe_view(&self.pipes, &(len, s, a, slot, dev_reduce))?;
            let sinos_host = pipe.sinos.download()?;
            let all = sinos_host.as_f32();
            for (i, feats_slot) in out[lo..hi].iter_mut().enumerate() {
                let mut feats = Vec::with_capacity(nt * 6);
                for ti in 0..nt {
                    let off = (i * nt + ti) * a * s;
                    feats.extend(reduce_sinogram(&all[off..off + a * s], a, s));
                }
                *feats_slot = feats;
            }
        }
        Ok(())
    }
}

pub struct GpuAuto {
    /// One lane per device. Lane 0 is the "home" device: the
    /// single-image path, the shard-off path, and the
    /// [`GpuAuto::launcher`] accessor all use it.
    lanes: Vec<DeviceLane>,
    mode: AutoMode,
    /// The angle table, replicated lazily across lanes — built once per
    /// distinct angle set (keyed by the raw bits) and reused across
    /// every subsequent call.
    angles: Option<(Vec<u32>, ReplicatedArray)>,
    /// The scheduling group behind a multi-lane pipeline: placement
    /// counters and per-member utilization stats. `None` on the classic
    /// single-device construction.
    set: Option<DeviceSet>,
    /// Per-instance sharding override; `None` defers to
    /// [`default_shard`] (`HLGPU_SHARD`).
    shard: Option<ShardMode>,
}

impl GpuAuto {
    pub fn new() -> Result<GpuAuto> {
        Self::on_device(DeviceChoice::Pjrt)
    }

    pub fn on_device(device: DeviceChoice) -> Result<GpuAuto> {
        match device {
            DeviceChoice::Pjrt => Ok(Self::single(DeviceLane::from_launcher(
                Launcher::with_default_context()?,
            ))),
            DeviceChoice::Emulator => {
                // `HLGPU_DEVICES` makes more than one emulator device
                // visible: build a lane per device so batches can shard.
                let devs = crate::driver::emulator_devices();
                if devs.len() > 1 {
                    return Self::on_set(DeviceSet::new(&devs)?);
                }
                let mut l = Launcher::emulator()?;
                register_trace_providers(l.registry_mut());
                Ok(Self::single(DeviceLane::from_launcher(l)))
            }
        }
    }

    /// A single-lane pipeline pinned to an existing context — how the
    /// serve layer binds one worker to one [`DeviceSet`] member.
    pub fn on_context(ctx: Context) -> Result<GpuAuto> {
        Ok(Self::single(DeviceLane::on_context(ctx)?))
    }

    /// A multi-lane pipeline over every member of `set`. Batches shard
    /// across the members under [`ShardMode::Auto`]; everything else
    /// (single-image calls, shard-off batches) runs on member 0.
    pub fn on_set(set: DeviceSet) -> Result<GpuAuto> {
        let mut lanes = Vec::with_capacity(set.len());
        for i in 0..set.len() {
            lanes.push(DeviceLane::on_context(set.context(i).clone())?);
        }
        Ok(GpuAuto {
            lanes,
            mode: AutoMode::SinogramAll,
            angles: None,
            set: Some(set),
            shard: None,
        })
    }

    fn single(lane: DeviceLane) -> GpuAuto {
        GpuAuto {
            lanes: vec![lane],
            mode: AutoMode::SinogramAll,
            angles: None,
            set: None,
            shard: None,
        }
    }

    pub fn with_mode(mut self, mode: AutoMode) -> Self {
        self.mode = mode;
        self
    }

    /// Per-instance sharding override (`Some(ShardMode::Off)` pins every
    /// batch to lane 0); `None` defers to `HLGPU_SHARD`.
    pub fn with_shard(mut self, shard: Option<ShardMode>) -> Self {
        self.shard = shard;
        self
    }

    /// Single-launch variant using the AOT fused full-pipeline graph.
    pub fn fused() -> Result<GpuAuto> {
        let ctx = Context::default_device()?;
        let registry = KernelRegistry::with_default_library()?;
        let mut auto = Self::single(DeviceLane::from_launcher(Launcher::new(ctx, registry)));
        auto.mode = AutoMode::TraceFull;
        Ok(auto)
    }

    pub fn launcher(&self) -> &Launcher {
        &self.lanes[0].launcher
    }

    pub fn launcher_mut(&mut self) -> &mut Launcher {
        &mut self.lanes[0].launcher
    }

    /// Number of device lanes this pipeline can shard across.
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    /// The scheduling group behind a multi-lane pipeline (per-member
    /// shard/image/busy counters), when one exists.
    pub fn device_set(&self) -> Option<&DeviceSet> {
        self.set.as_ref()
    }

    /// Lane 0's stream pool, once a batch has built it — the serve layer
    /// and benches read its lease/quarantine counters.
    pub fn stream_pool(&self) -> Option<&StreamPool> {
        self.lanes[0].streams.as_ref()
    }

    /// True when this call's P/F stage runs on the device: the default
    /// placement (`HLGPU_REDUCE`) on the emulator backend, fused
    /// single-launch mode excluded (only the VTX registry carries the
    /// `circus_all`/`features_all` lowerings).
    fn device_reduce(&self) -> bool {
        self.mode == AutoMode::SinogramAll
            && self.lanes[0].launcher.context().device().kind == BackendKind::VtxEmulator
            && default_reduce() == ReduceMode::Device
    }

    /// The replicated angle table for `thetas`, rebuilt only when the
    /// set changes; per-lane uploads happen lazily inside `run_chunks`.
    fn angle_table(&mut self, thetas: &[f32]) -> Result<()> {
        let key: Vec<u32> = thetas.iter().map(|t| t.to_bits()).collect();
        let stale = match &self.angles {
            Some((k, _)) => *k != key,
            None => true,
        };
        if stale {
            let t = Tensor::from_f32(thetas, &[thetas.len()]);
            self.angles = Some((key, ReplicatedArray::new(t)));
        }
        Ok(())
    }
}

impl TraceImpl for GpuAuto {
    fn name(&self) -> &'static str {
        match self.mode {
            AutoMode::SinogramAll => "gpu-auto",
            AutoMode::PerFunctional => "gpu-auto-staged",
            AutoMode::TraceFull => "gpu-auto-fused",
        }
    }

    fn features(&mut self, img: &Image, thetas: &[f32]) -> Result<Vec<f32>> {
        // SLOC:core-begin
        let s = img.size();
        let a = thetas.len();
        let nt = T_SET.len();
        let img_t = img.to_tensor();
        let angles_t = Tensor::from_f32(thetas, &[a]);
        let dev_reduce = self.device_reduce();
        let lane = &mut self.lanes[0];

        match self.mode {
            AutoMode::TraceFull => {
                // one launch of the L2-fused pipeline
                let mut out =
                    Tensor::zeros_f32(&[crate::tracetransform::functionals::FEATURE_COUNT]);
                lane.launcher.launch(
                    "trace_full",
                    checked_cfg("trace_full", a, s)?,
                    &mut [arg::cu_in(&img_t), arg::cu_in(&angles_t), arg::cu_out(&mut out)],
                )?;
                Ok(out.to_vec_f32())
            }
            AutoMode::SinogramAll if dev_reduce => {
                // Fully resident chain: the sinograms and circus
                // functions never leave the device; the only d2h is the
                // FEATURE_COUNT-float block.
                let np = P_SET.len();
                if !lane.reduce_bufs.contains_key(&(s, a)) {
                    let ctx = lane.launcher.context().clone();
                    lane.reduce_bufs.insert(
                        (s, a),
                        ReduceBufs {
                            sinos: DeviceArray::alloc(&ctx, Dtype::F32, &[nt, a, s])?,
                            circus: DeviceArray::alloc(&ctx, Dtype::F32, &[nt, np, a])?,
                            feats: DeviceArray::alloc(&ctx, Dtype::F32, &[FEATURE_COUNT])?,
                        },
                    );
                }
                let bufs = reduce_entry(&mut lane.reduce_bufs, (s, a))?;
                lane.launcher.launch(
                    "sinogram_all",
                    checked_cfg("sinogram_all", a, s)?,
                    &mut [
                        arg::cu_in(&img_t),
                        arg::cu_in(&angles_t),
                        arg::cu_dev_mut(&mut bufs.sinos),
                    ],
                )?;
                lane.launcher.launch(
                    "circus_all",
                    checked_cfg("circus_all", a, s)?,
                    &mut [arg::cu_dev(&bufs.sinos), arg::cu_dev_mut(&mut bufs.circus)],
                )?;
                lane.launcher.launch(
                    "features_all",
                    checked_cfg("features_all", np, a)?,
                    &mut [arg::cu_dev(&bufs.circus), arg::cu_dev_mut(&mut bufs.feats)],
                )?;
                Ok(bufs.feats.download()?.to_vec_f32())
            }
            AutoMode::SinogramAll => {
                // @cuda (a, s) sinogram_all(CuIn(img), CuIn(angles), CuOut(sinos))
                let mut sinos = Tensor::zeros_f32(&[nt, a, s]);
                lane.launcher.launch(
                    "sinogram_all",
                    checked_cfg("sinogram_all", a, s)?,
                    &mut [arg::cu_in(&img_t), arg::cu_in(&angles_t), arg::cu_out(&mut sinos)],
                )?;
                let all = sinos.as_f32();
                let mut feats = Vec::with_capacity(nt * 6);
                for ti in 0..nt {
                    feats.extend(reduce_sinogram(&all[ti * a * s..(ti + 1) * a * s], a, s));
                }
                Ok(feats)
            }
            AutoMode::PerFunctional => {
                // the paper's structure: one kernel per T-functional,
                // @cuda (a, s) sinogram_t(CuIn(img), CuIn(angles), CuOut(sino))
                let mut feats = Vec::with_capacity(nt * 6);
                let mut sino = Tensor::zeros_f32(&[a, s]);
                for t in T_SET {
                    lane.launcher.launch(
                        &format!("sinogram_{}", t.name()),
                        checked_cfg(&format!("sinogram_{}", t.name()), a, s)?,
                        &mut [
                            arg::cu_in(&img_t),
                            arg::cu_in(&angles_t),
                            arg::cu_out(&mut sino),
                        ],
                    )?;
                    feats.extend(reduce_sinogram(sino.as_f32(), a, s));
                }
                Ok(feats)
            }
        }
        // SLOC:core-end
    }

    /// Batched path, launch API v2: the batch splits into chunks
    /// processed through a double-buffered two-stream pipeline — on one
    /// lane (classic two-chunk split), or sharded across every lane of a
    /// multi-device pipeline under [`ShardMode::Auto`]. The angle table
    /// and all kernel buffers are device-resident — the only
    /// host↔device traffic at steady state is one stacked-image upload
    /// per chunk and one result download per chunk; the
    /// `batched_sinogram` handles launch with zero specialization-cache
    /// traffic. Sharded output is reassembled by image index and is
    /// bitwise identical to the single-lane path.
    fn features_batch(&mut self, imgs: &[Image], thetas: &[f32]) -> Result<Vec<Vec<f32>>> {
        if imgs.is_empty() {
            return Ok(Vec::new());
        }
        let batched_ok = self.mode == AutoMode::SinogramAll
            && self.lanes[0].launcher.context().device().kind == BackendKind::VtxEmulator
            && imgs.iter().all(|i| i.size() == imgs[0].size());
        if !batched_ok {
            // PJRT artifacts and the ablation modes have no batched
            // lowering — sequential fallback
            return imgs.iter().map(|img| self.features(img, thetas)).collect();
        }
        let n = imgs.len();
        let dev_reduce = self.device_reduce();
        let shard = self.shard.unwrap_or_else(default_shard);
        self.angle_table(thetas)?;

        let set = if shard == ShardMode::Auto && self.lanes.len() > 1 && n >= 2 {
            self.set.clone()
        } else {
            None
        };
        let rep = angle_entry(&self.angles)?;
        let mut out = vec![Vec::new(); n];
        let set = match set {
            None => {
                // Classic single-device path (and the shard-off
                // differential reference): two chunks double-buffer —
                // chunk 1's upload overlaps chunk 0's compute. A
                // singleton batch degenerates to one chunk.
                let half = n.div_ceil(2);
                let mut chunks = vec![(0usize, half)];
                if half < n {
                    chunks.push((half, n));
                }
                if let Err(e) = self.lanes[0].run_chunks(imgs, &chunks, rep, dev_reduce, &mut out)
                {
                    // Surface the typed error but never a poisoned warm
                    // path: the next call rebuilds the lane's caches.
                    self.lanes[0].invalidate();
                    return Err(e);
                }
                return Ok(out);
            }
            Some(s) => s,
        };

        // Sharded path. Deterministic contiguous chunking: double-buffer
        // depth (two chunks) per lane, but never more chunks than
        // images.
        let nlanes = self.lanes.len();
        let nchunks = (2 * nlanes).min(n);
        let per = n.div_ceil(nchunks);
        let mut chunks = Vec::with_capacity(nchunks);
        let mut next = 0usize;
        while next < n {
            let hi = (next + per).min(n);
            chunks.push((next, hi));
            next = hi;
        }
        // Serial placement in chunk order: least outstanding work, ties
        // to the lowest member — deterministic for a quiet set.
        let mut per_lane: Vec<Vec<(usize, usize)>> = vec![Vec::new(); nlanes];
        for &(clo, chi) in &chunks {
            let m = set.place((chi - clo) as u64);
            per_lane[m].push((clo, chi));
        }
        // One thread per lane with placed work; each runs its own
        // double-buffered pipeline on its own context, so the only
        // shared state is the replicated angle table (internally
        // locked) and the set's counters (atomics).
        let lane_results: Vec<(usize, Result<Vec<Vec<f32>>>)> = std::thread::scope(|scope| {
            let mut joins = Vec::new();
            for (li, (lane, lane_chunks)) in
                self.lanes.iter_mut().zip(per_lane.iter()).enumerate()
            {
                if lane_chunks.is_empty() {
                    continue;
                }
                let set = set.clone();
                joins.push((
                    li,
                    scope.spawn(move || {
                        let start = std::time::Instant::now();
                        let mut local = vec![Vec::new(); n];
                        let r = lane.run_chunks(imgs, lane_chunks, rep, dev_reduce, &mut local);
                        let weight: u64 =
                            lane_chunks.iter().map(|&(lo, hi)| (hi - lo) as u64).sum();
                        set.complete(li, weight);
                        set.record_busy(li, start.elapsed().as_nanos() as u64);
                        if r.is_ok() {
                            set.record_images(li, weight);
                        }
                        r.map(|()| local)
                    }),
                ));
            }
            joins
                .into_iter()
                .map(|(li, h)| {
                    (
                        li,
                        h.join().unwrap_or_else(|_| {
                            Err(Error::Other("a sharded pipeline lane panicked".into()))
                        }),
                    )
                })
                .collect()
        });
        // Reassemble the successful lanes by global image index — each
        // image's features depend only on its own pixels, so the shard
        // composition leaves the bits unchanged relative to
        // single-device execution — and collect the failed lanes for
        // the bounded failover retry below.
        let mut failed: Vec<(usize, Error)> = Vec::new();
        for (li, r) in lane_results {
            match r {
                Ok(mut local) => {
                    for &(clo, chi) in &per_lane[li] {
                        for (slot, got) in out[clo..chi].iter_mut().zip(local[clo..chi].iter_mut())
                        {
                            *slot = std::mem::take(got);
                        }
                    }
                }
                Err(e) => failed.push((li, e)),
            }
        }
        // Failover: a failed lane marks its member's health and drops
        // its warm caches (they may be desynced mid-chain). Device-loss
        // and transient failures get one retry per chunk, re-placed on
        // the surviving members — the health-aware `place` skips the
        // lost one. Retried shards recompute the same per-image pure
        // function, so the reassembled batch stays bitwise identical to
        // a fault-free run.
        for (li, e) in failed {
            set.observe_error(li, &e);
            self.lanes[li].invalidate();
            if !(e.is_device_loss() || e.is_transient()) {
                return Err(e);
            }
            for &(clo, chi) in &per_lane[li] {
                let weight = (chi - clo) as u64;
                let m = set.place(weight);
                if m == li {
                    // No healthier member to fail over to.
                    set.complete(m, weight);
                    return Err(e);
                }
                let start = std::time::Instant::now();
                let mut local = vec![Vec::new(); n];
                let r = self.lanes[m].run_chunks(imgs, &[(clo, chi)], rep, dev_reduce, &mut local);
                set.complete(m, weight);
                set.record_busy(m, start.elapsed().as_nanos() as u64);
                match r {
                    Ok(()) => {
                        set.record_images(m, weight);
                        for (slot, got) in out[clo..chi].iter_mut().zip(local[clo..chi].iter_mut())
                        {
                            *slot = std::mem::take(got);
                        }
                    }
                    Err(e2) => {
                        set.observe_error(m, &e2);
                        self.lanes[m].invalidate();
                        return Err(e2);
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracetransform::functionals::FEATURE_COUNT;
    use crate::tracetransform::image::{orientations, shepp_logan};

    use crate::tracetransform::impls::REDUCE_TEST_LOCK;

    #[test]
    fn batched_pipeline_specializes_once_per_chunk_shape() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let thetas = orientations(5);
        let imgs: Vec<_> = (0..3)
            .map(|i| crate::tracetransform::image::random_phantom(10, i as u64))
            .collect();
        // Counts below are per-lane-0; pin sharding off so they hold
        // under `HLGPU_DEVICES>1`.
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator)
            .unwrap()
            .with_shard(Some(ShardMode::Off));
        // 3 images split into chunks of 2 and 1 — two call shapes; the
        // device-reduce chain binds 3 kernels per shape, the host path 1
        let per_shape: u64 = if m.device_reduce() { 3 } else { 1 };
        let b1 = m.features_batch(&imgs, &thetas).unwrap();
        assert_eq!(m.launcher().metrics().cold_specializations, 2 * per_shape);
        let b2 = m.features_batch(&imgs, &thetas).unwrap();
        assert_eq!(b1, b2);
        assert_eq!(
            m.launcher().metrics().cold_specializations,
            2 * per_shape,
            "warm batch re-specializes nothing"
        );
        // a 2-image batch splits into two length-1 chunks — the length-1
        // shapes are already specialized, so binding the new slot's
        // handles hits the cache and re-specializes nothing
        m.features_batch(&imgs[..2], &thetas).unwrap();
        assert_eq!(m.launcher().metrics().cold_specializations, 2 * per_shape);
        // cache stats confirm the handles bypass the cache on the warm
        // path: only the bind() calls touched it
        let st = m.launcher().cache_stats();
        assert_eq!(st.misses, 2 * per_shape);
    }

    #[test]
    fn warm_batch_moves_only_images_and_results() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let thetas = orientations(5);
        let imgs: Vec<_> = (0..4)
            .map(|i| crate::tracetransform::image::random_phantom(10, 20 + i as u64))
            .collect();
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator)
            .unwrap()
            .with_shard(Some(ShardMode::Off));
        m.features_batch(&imgs, &thetas).unwrap(); // cold: builds pipes
        m.launcher().context().memory().unwrap().reset_stats();
        m.features_batch(&imgs, &thetas).unwrap();
        let st = m.launcher().context().mem_stats().unwrap();
        assert_eq!(st.alloc_count, 0, "warm batch allocates nothing");
        assert_eq!(st.h2d_count, 2, "one stacked upload per chunk, no angle re-upload");
        assert_eq!(st.d2h_count, 2, "one result download per chunk");
        // the device-resident skips are visible in the launch metrics
        let lm = m.launcher().metrics();
        assert!(lm.skipped_h2d > 0);
        assert!(lm.skipped_d2h > 0);
    }

    /// PR-5 acceptance criterion: on the device-reduce path a warm
    /// batched run performs **zero sinogram d2h transfers** — the bytes
    /// downloaded per image are exactly the `FEATURE_COUNT`-float block,
    /// asserted through both `MemStats` and the `LaunchMetrics`
    /// deferred-readback counters.
    #[test]
    fn device_reduce_batch_downloads_only_feature_blocks() {
        use crate::tracetransform::impls::set_default_reduce;
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_default_reduce(Some(ReduceMode::Device));
        let thetas = orientations(6);
        let imgs: Vec<_> = (0..5)
            .map(|i| crate::tracetransform::image::random_phantom(12, 90 + i as u64))
            .collect();
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator)
            .unwrap()
            .with_shard(Some(ShardMode::Off));
        m.features_batch(&imgs, &thetas).unwrap(); // cold
        m.launcher().context().memory().unwrap().reset_stats();
        let lm_before = m.launcher().metrics();
        m.features_batch(&imgs, &thetas).unwrap();
        let st = m.launcher().context().mem_stats().unwrap();
        assert_eq!(
            st.d2h_bytes,
            (imgs.len() * FEATURE_COUNT * 4) as u64,
            "per-image download bytes == FEATURE_COUNT * 4"
        );
        let lm = m.launcher().metrics();
        assert_eq!(lm.d2h_deferred - lm_before.d2h_deferred, 2, "one async readback per chunk");
        assert_eq!(
            lm.features_bytes - lm_before.features_bytes,
            (imgs.len() * FEATURE_COUNT * 4) as u64
        );
        set_default_reduce(None);
    }

    /// The two reduce placements are observationally identical (up to
    /// reduction-order rounding) through the same pipeline object.
    #[test]
    fn host_and_device_reduce_agree() {
        use crate::tracetransform::impls::set_default_reduce;
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let img = shepp_logan(14);
        let thetas = orientations(7);
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        set_default_reduce(Some(ReduceMode::Host));
        let host = m.features(&img, &thetas).unwrap();
        set_default_reduce(Some(ReduceMode::Device));
        let dev = m.features(&img, &thetas).unwrap();
        set_default_reduce(None);
        assert_eq!(host.len(), FEATURE_COUNT);
        for (i, (h, d)) in host.iter().zip(&dev).enumerate() {
            assert!((h - d).abs() < 1e-4 * h.abs().max(1.0), "feature {i}: {h} vs {d}");
        }
    }

    /// Satellite regression (PR-6): the warm-path cache lookups return a
    /// typed error on desynced internal state instead of panicking — a
    /// `features_batch` call that hits a missing pipe/angle-table/reduce
    /// buffer fails that one call, not the process.
    #[test]
    fn desynced_pipe_cache_errors_instead_of_panicking() {
        let mut pipes: HashMap<PipeKey, ChunkPipe> = HashMap::new();
        let err = pipe_entry(&mut pipes, &(2, 10, 5, 0, true)).unwrap_err();
        assert!(matches!(err, Error::InvalidLaunch(_)), "got {err}");
        assert!(err.to_string().contains("state desynced"), "{err}");
        let err = pipe_view(&pipes, &(2, 10, 5, 0, true)).unwrap_err();
        assert!(matches!(err, Error::InvalidLaunch(_)), "got {err}");
        let err = angle_entry(&None).unwrap_err();
        assert!(err.to_string().contains("angle table"), "{err}");
        let mut bufs: HashMap<(usize, usize), ReduceBufs> = HashMap::new();
        let err = reduce_entry(&mut bufs, (10, 5)).unwrap_err();
        assert!(matches!(err, Error::InvalidLaunch(_)), "got {err}");
    }

    /// Clearing every piece of cached pipeline state mid-life and
    /// rerunning rebuilds it and produces bitwise-identical features —
    /// the desync error above is about *partial* loss, full rebuild is
    /// always safe.
    #[test]
    fn pipe_cache_rebuild_after_clear_keeps_results_identical() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let thetas = orientations(6);
        let imgs: Vec<_> = (0..3)
            .map(|i| crate::tracetransform::image::random_phantom(11, 70 + i as u64))
            .collect();
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        let before = m.features_batch(&imgs, &thetas).unwrap();
        m.angles = None;
        for lane in &mut m.lanes {
            lane.pipes.clear();
            lane.reduce_bufs.clear();
        }
        let after = m.features_batch(&imgs, &thetas).unwrap();
        assert_eq!(before, after, "rebuilt pipeline is bitwise-identical");
    }

    /// The batched path leases its stream pair from a pool instead of
    /// owning streams: two warm batches lease the same two streams (so
    /// their pool arenas — and the warm-path zero-alloc invariant — are
    /// stable) and return them clean.
    #[test]
    fn batched_pipeline_pools_its_streams() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let thetas = orientations(5);
        let imgs: Vec<_> = (0..4)
            .map(|i| crate::tracetransform::image::random_phantom(10, 50 + i as u64))
            .collect();
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator)
            .unwrap()
            .with_shard(Some(ShardMode::Off));
        m.features_batch(&imgs, &thetas).unwrap();
        m.features_batch(&imgs, &thetas).unwrap();
        let pool = m.stream_pool().expect("pool built on first batch");
        let st = pool.stats();
        assert_eq!(st.created, 2, "pool creates exactly the double-buffer pair");
        assert_eq!(st.leases, 4, "two leases per batch");
        assert_eq!(st.quarantined, 0, "clean batches quarantine nothing");
        assert_eq!(pool.idle_count(), 2, "both streams returned after the batch");
    }

    /// Warm-path poisoning regression: an injected transient fault
    /// fails one batch, the lane's cached pipes/reduce-bufs/streams are
    /// invalidated, and the *next* call rebuilds them and succeeds with
    /// bitwise-identical output — no sticky `InvalidLaunch` from a
    /// desynced cache.
    #[test]
    fn failed_batch_invalidates_warm_caches_and_next_call_succeeds() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let _f = crate::driver::faults::FAULT_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let thetas = orientations(5);
        let imgs: Vec<_> = (0..4)
            .map(|i| crate::tracetransform::image::random_phantom(10, 700 + i as u64))
            .collect();
        let mut reference = GpuAuto::on_device(DeviceChoice::Emulator)
            .unwrap()
            .with_shard(Some(ShardMode::Off));
        let expect = reference.features_batch(&imgs, &thetas).unwrap();

        // A synthesized ordinal only this test touches: parallel tests
        // doing h2d on the shared emulator device must not consume (or
        // trip over) the scheduled injection.
        let ord = 9_300usize;
        let ctx = Context::create(&crate::driver::Device::emulator_at(ord, None)).unwrap();
        let mut m = GpuAuto::on_context(ctx).unwrap().with_shard(Some(ShardMode::Off));
        crate::driver::faults::install(
            crate::driver::faults::FaultPlan::new().fail(
                crate::driver::faults::FaultSite::H2d,
                ord,
                1,
            ),
        );
        let err = m.features_batch(&imgs, &thetas).unwrap_err();
        assert!(err.is_transient(), "injected h2d fault is transient: {err}");
        assert!(!err.is_device_loss());
        // The rule fired exactly once; the rebuilt warm path succeeds.
        let got = m.features_batch(&imgs, &thetas).unwrap();
        assert_eq!(got, expect, "post-failure rebuild is bitwise identical");
        crate::driver::faults::reset_all();
    }

    /// Tentpole acceptance criterion: a batch sharded across a
    /// 2- or 4-member `DeviceSet` is **bitwise identical** to the
    /// single-device pipeline, and the set's accounting shows the work
    /// actually spread and every shard retired.
    #[test]
    fn sharded_batch_is_bitwise_identical_to_single_device() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let thetas = orientations(7);
        let imgs: Vec<_> = (0..9)
            .map(|i| crate::tracetransform::image::random_phantom(12, 200 + i as u64))
            .collect();
        let mut single = GpuAuto::on_device(DeviceChoice::Emulator)
            .unwrap()
            .with_shard(Some(ShardMode::Off));
        let reference = single.features_batch(&imgs, &thetas).unwrap();
        for k in [2usize, 4] {
            let set = DeviceSet::emulator(k).unwrap();
            let mut sharded = GpuAuto::on_set(set)
                .unwrap()
                .with_shard(Some(ShardMode::Auto));
            assert_eq!(sharded.lane_count(), k);
            let got = sharded.features_batch(&imgs, &thetas).unwrap();
            assert_eq!(got, reference, "{k}-device shard must be bitwise identical");
            let stats = sharded.device_set().unwrap().stats();
            let total: u64 = stats.iter().map(|s| s.images).sum();
            assert_eq!(total, imgs.len() as u64, "every image accounted to a member");
            assert!(stats.iter().all(|s| s.outstanding == 0), "all shards retired");
            // Under an ambient chaos schedule (HLGPU_FAULTS) a member
            // may be lost and excluded from placement — the bitwise
            // identity above still must hold, but the spread may
            // legitimately collapse onto the survivors.
            assert!(
                crate::driver::faults::armed()
                    || stats.iter().filter(|s| s.images > 0).count() >= 2,
                "work spread across members: {stats:?}"
            );
        }
    }

    /// Shard-off on a multi-lane pipeline is the single-device path:
    /// nothing moves through the set, and the other members' contexts
    /// see zero traffic.
    #[test]
    fn shard_off_runs_everything_on_lane_zero() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let thetas = orientations(5);
        let imgs: Vec<_> = (0..4)
            .map(|i| crate::tracetransform::image::random_phantom(10, 300 + i as u64))
            .collect();
        let set = DeviceSet::emulator(2).unwrap();
        let mut m = GpuAuto::on_set(set).unwrap().with_shard(Some(ShardMode::Off));
        m.features_batch(&imgs, &thetas).unwrap();
        let stats = m.device_set().unwrap().stats();
        assert!(stats.iter().all(|s| s.images == 0), "shard-off bypasses the set: {stats:?}");
        let idle = m.device_set().unwrap().context(1).mem_stats().unwrap();
        assert_eq!(idle.h2d_count, 0, "member 1 saw no uploads");
        assert_eq!(idle.alloc_count, 0, "member 1 allocated nothing");
    }

    #[test]
    fn emulator_auto_runs_and_caches() {
        let _g = REDUCE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let img = shepp_logan(12);
        let thetas = orientations(5);
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        // device reduce: sinogram_all + circus_all + features_all;
        // host reduce: the fused sinogram_all only
        let expect_cold = if m.device_reduce() { 3 } else { 1 };
        let f1 = m.features(&img, &thetas).unwrap();
        assert_eq!(f1.len(), FEATURE_COUNT);
        let cold = m.launcher().metrics().cold_specializations;
        assert_eq!(cold, expect_cold);
        // second call: fully warm
        let f2 = m.features(&img, &thetas).unwrap();
        assert_eq!(f1, f2);
        assert_eq!(m.launcher().metrics().cold_specializations, cold);
    }
}
