//! Implementation 5 — "Julia (CPU + GPU)": the full framework. Kernels
//! are launched through the automation layer (`Launcher`, the `@cuda`
//! analog): arguments wrapped `CuIn`/`CuOut`, specialization cached per
//! signature, transfers minimized, module management invisible — the host
//! code shrinks to the paper's Listing 3.
//!
//! The batched path uses the **launch API v2** (see `docs/api.md`): the
//! angle table and the image/sinogram buffers are device-resident
//! (`arg::cu_dev` / `cu_dev_mut`), the `batched_sinogram` kernel is a
//! bound [`KernelHandle`] launched with zero cache traffic, and the batch
//! is split into two chunks whose uploads (on a dedicated upload stream,
//! allocating from its own pool arena) overlap the other chunk's compute
//! (on a second stream, fenced by events) — the double-buffered pipeline.

use std::collections::HashMap;

use crate::coordinator::{arg, DeviceArray, KernelHandle, KernelRegistry, Launcher};
use crate::driver::{BackendKind, Context, Event, LaunchConfig, Stream};
use crate::error::Result;
use crate::tensor::{Dtype, Tensor};
use crate::tracetransform::functionals::{reduce_sinogram, T_SET};
use crate::tracetransform::image::Image;
use crate::tracetransform::impls::{register_trace_providers, DeviceChoice, TraceImpl};

/// Which kernel structure the automated path launches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutoMode {
    /// One fused `sinogram_all` launch per image (the optimized default).
    SinogramAll,
    /// One launch per T-functional (the paper's original 5-kernel
    /// structure; §Perf "before" configuration).
    PerFunctional,
    /// One `trace_full` launch: the whole pipeline, P/F included, on
    /// device (L2 composition; PJRT artifacts only).
    TraceFull,
}

/// One double-buffer slot of the batched pipeline: a bound kernel handle
/// plus device-resident image and sinogram buffers for a fixed chunk
/// length.
struct ChunkPipe {
    handle: KernelHandle,
    imgs: DeviceArray,
    sinos: DeviceArray,
}

pub struct GpuAuto {
    launcher: Launcher,
    mode: AutoMode,
    /// Device-resident angle table, uploaded once per distinct angle set
    /// and reused across every subsequent call (keyed by the raw bits).
    angles_dev: Option<(Vec<u32>, DeviceArray)>,
    /// Double-buffer pipeline state keyed by (chunk_len, size, angles,
    /// slot) — two slots so chunk i+1's upload overlaps chunk i's
    /// compute without aliasing buffers.
    pipes: HashMap<(usize, usize, usize, usize), ChunkPipe>,
    upload_stream: Option<Stream>,
    compute_stream: Option<Stream>,
}

impl GpuAuto {
    pub fn new() -> Result<GpuAuto> {
        Self::on_device(DeviceChoice::Pjrt)
    }

    pub fn on_device(device: DeviceChoice) -> Result<GpuAuto> {
        let launcher = match device {
            DeviceChoice::Pjrt => Launcher::with_default_context()?,
            DeviceChoice::Emulator => {
                let mut l = Launcher::emulator()?;
                register_trace_providers(l.registry_mut());
                l
            }
        };
        Ok(GpuAuto {
            launcher,
            mode: AutoMode::SinogramAll,
            angles_dev: None,
            pipes: HashMap::new(),
            upload_stream: None,
            compute_stream: None,
        })
    }

    pub fn with_mode(mut self, mode: AutoMode) -> Self {
        self.mode = mode;
        self
    }

    /// Single-launch variant using the AOT fused full-pipeline graph.
    pub fn fused() -> Result<GpuAuto> {
        let ctx = Context::default_device()?;
        let registry = KernelRegistry::with_default_library()?;
        Ok(GpuAuto {
            launcher: Launcher::new(ctx, registry),
            mode: AutoMode::TraceFull,
            angles_dev: None,
            pipes: HashMap::new(),
            upload_stream: None,
            compute_stream: None,
        })
    }

    pub fn launcher(&self) -> &Launcher {
        &self.launcher
    }

    pub fn launcher_mut(&mut self) -> &mut Launcher {
        &mut self.launcher
    }

    /// The device-resident angle table for `thetas`, uploading only when
    /// the set changes.
    fn angle_table(&mut self, thetas: &[f32]) -> Result<()> {
        let key: Vec<u32> = thetas.iter().map(|t| t.to_bits()).collect();
        let stale = match &self.angles_dev {
            Some((k, _)) => *k != key,
            None => true,
        };
        if stale {
            let t = Tensor::from_f32(thetas, &[thetas.len()]);
            let arr = DeviceArray::from_tensor(self.launcher.context(), &t)?;
            self.angles_dev = Some((key, arr));
        }
        Ok(())
    }
}

impl TraceImpl for GpuAuto {
    fn name(&self) -> &'static str {
        match self.mode {
            AutoMode::SinogramAll => "gpu-auto",
            AutoMode::PerFunctional => "gpu-auto-staged",
            AutoMode::TraceFull => "gpu-auto-fused",
        }
    }

    fn features(&mut self, img: &Image, thetas: &[f32]) -> Result<Vec<f32>> {
        // SLOC:core-begin
        let s = img.size();
        let a = thetas.len();
        let nt = T_SET.len();
        let img_t = img.to_tensor();
        let angles_t = Tensor::from_f32(thetas, &[a]);

        match self.mode {
            AutoMode::TraceFull => {
                // one launch of the L2-fused pipeline
                let mut out =
                    Tensor::zeros_f32(&[crate::tracetransform::functionals::FEATURE_COUNT]);
                self.launcher.launch(
                    "trace_full",
                    LaunchConfig::new(a as u32, s as u32),
                    &mut [arg::cu_in(&img_t), arg::cu_in(&angles_t), arg::cu_out(&mut out)],
                )?;
                Ok(out.to_vec_f32())
            }
            AutoMode::SinogramAll => {
                // @cuda (a, s) sinogram_all(CuIn(img), CuIn(angles), CuOut(sinos))
                let mut sinos = Tensor::zeros_f32(&[nt, a, s]);
                self.launcher.launch(
                    "sinogram_all",
                    LaunchConfig::new(a as u32, s as u32),
                    &mut [arg::cu_in(&img_t), arg::cu_in(&angles_t), arg::cu_out(&mut sinos)],
                )?;
                let all = sinos.as_f32();
                let mut feats = Vec::with_capacity(nt * 6);
                for ti in 0..nt {
                    feats.extend(reduce_sinogram(&all[ti * a * s..(ti + 1) * a * s], a, s));
                }
                Ok(feats)
            }
            AutoMode::PerFunctional => {
                // the paper's structure: one kernel per T-functional,
                // @cuda (a, s) sinogram_t(CuIn(img), CuIn(angles), CuOut(sino))
                let mut feats = Vec::with_capacity(nt * 6);
                let mut sino = Tensor::zeros_f32(&[a, s]);
                for t in T_SET {
                    self.launcher.launch(
                        &format!("sinogram_{}", t.name()),
                        LaunchConfig::new(a as u32, s as u32),
                        &mut [
                            arg::cu_in(&img_t),
                            arg::cu_in(&angles_t),
                            arg::cu_out(&mut sino),
                        ],
                    )?;
                    feats.extend(reduce_sinogram(sino.as_f32(), a, s));
                }
                Ok(feats)
            }
        }
        // SLOC:core-end
    }

    /// Batched path, launch API v2: the batch splits into two chunks
    /// processed through a double-buffered two-stream pipeline. The
    /// angle table and all kernel buffers are device-resident — the only
    /// host↔device traffic at steady state is one stacked-image upload
    /// per chunk and one sinogram download per chunk; the
    /// `batched_sinogram` handle launches with zero specialization-cache
    /// traffic.
    fn features_batch(&mut self, imgs: &[Image], thetas: &[f32]) -> Result<Vec<Vec<f32>>> {
        if imgs.is_empty() {
            return Ok(Vec::new());
        }
        let batched_ok = self.mode == AutoMode::SinogramAll
            && self.launcher.context().device().kind == BackendKind::VtxEmulator
            && imgs.iter().all(|i| i.size() == imgs[0].size());
        if !batched_ok {
            // PJRT artifacts and the ablation modes have no batched
            // lowering — sequential fallback
            return imgs.iter().map(|img| self.features(img, thetas)).collect();
        }
        let s = imgs[0].size();
        let n = imgs.len();
        let a = thetas.len();
        let nt = T_SET.len();

        let ctx = self.launcher.context().clone();
        if self.upload_stream.is_none() {
            self.upload_stream = Some(ctx.create_stream()?);
            self.compute_stream = Some(ctx.create_stream()?);
        }
        self.angle_table(thetas)?;

        // Two chunks double-buffer: chunk 1's upload overlaps chunk 0's
        // compute. A singleton batch degenerates to one chunk.
        let half = n.div_ceil(2);
        let mut bounds = vec![(0usize, half)];
        if half < n {
            bounds.push((half, n));
        }

        // Bind handles + allocate device buffers per (chunk shape, slot),
        // reused across batches. Image buffers live in the upload
        // stream's arena, sinograms in the compute stream's — concurrent
        // stages allocate and copy without sharing a pool lock.
        for (slot, &(lo, hi)) in bounds.iter().enumerate() {
            let len = hi - lo;
            let key = (len, s, a, slot);
            if !self.pipes.contains_key(&key) {
                let up_arena = self.upload_stream.as_ref().unwrap().arena_id();
                let co_arena = self.compute_stream.as_ref().unwrap().arena_id();
                let imgs_dev = DeviceArray::alloc_in(&ctx, up_arena, Dtype::F32, &[len, s, s])?;
                let mut sinos_dev =
                    DeviceArray::alloc_in(&ctx, co_arena, Dtype::F32, &[len, nt, a, s])?;
                let (_, angles_dev) = self.angles_dev.as_ref().unwrap();
                let handle = self.launcher.bind(
                    "batched_sinogram",
                    &[
                        arg::cu_dev(&imgs_dev),
                        arg::cu_dev(angles_dev),
                        arg::cu_dev_mut(&mut sinos_dev),
                    ],
                )?;
                self.pipes.insert(key, ChunkPipe { handle, imgs: imgs_dev, sinos: sinos_dev });
            }
        }

        // Stage 1+2: enqueue every chunk's upload (stream U) and launch
        // (stream C, fenced on the upload's event) before joining any —
        // that is what overlaps the stages.
        let mem = ctx.memory_arc()?;
        let upload = self.upload_stream.as_ref().unwrap();
        let compute = self.compute_stream.as_ref().unwrap();
        let mut pendings = Vec::with_capacity(bounds.len());
        for (slot, &(lo, hi)) in bounds.iter().enumerate() {
            let len = hi - lo;
            let pipe = self.pipes.get_mut(&(len, s, a, slot)).unwrap();
            let mut bytes = Vec::with_capacity(len * s * s * 4);
            for img in &imgs[lo..hi] {
                for v in img.pixels() {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
            }
            upload.copy_h2d(mem.clone(), pipe.imgs.ptr(), bytes)?;
            let uploaded = Event::new();
            upload.record_event(&uploaded)?;
            compute.wait_event(&uploaded)?;
            let (_, angles_dev) = self.angles_dev.as_ref().unwrap();
            let pending = pipe.handle.launch_on(
                compute,
                LaunchConfig::new((a as u32, len as u32), s as u32),
                &mut [
                    arg::cu_dev(&pipe.imgs),
                    arg::cu_dev(angles_dev),
                    arg::cu_dev_mut(&mut pipe.sinos),
                ],
            )?;
            pendings.push((slot, lo, hi, pending));
        }

        // Stage 3: join chunks in order, download each chunk's sinograms
        // once, and reduce on the host.
        let mut out = vec![Vec::new(); n];
        for (slot, lo, hi, pending) in pendings {
            pending.wait()?;
            let len = hi - lo;
            let pipe = self.pipes.get(&(len, s, a, slot)).unwrap();
            let sinos_host = pipe.sinos.download()?;
            let all = sinos_host.as_f32();
            for (i, feats_slot) in out[lo..hi].iter_mut().enumerate() {
                let mut feats = Vec::with_capacity(nt * 6);
                for ti in 0..nt {
                    let off = (i * nt + ti) * a * s;
                    feats.extend(reduce_sinogram(&all[off..off + a * s], a, s));
                }
                *feats_slot = feats;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracetransform::functionals::FEATURE_COUNT;
    use crate::tracetransform::image::{orientations, shepp_logan};

    #[test]
    fn batched_pipeline_specializes_once_per_chunk_shape() {
        let thetas = orientations(5);
        let imgs: Vec<_> = (0..3)
            .map(|i| crate::tracetransform::image::random_phantom(10, i as u64))
            .collect();
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        let b1 = m.features_batch(&imgs, &thetas).unwrap();
        // 3 images split into chunks of 2 and 1 — two call shapes
        assert_eq!(m.launcher().metrics().cold_specializations, 2);
        let b2 = m.features_batch(&imgs, &thetas).unwrap();
        assert_eq!(b1, b2);
        assert_eq!(
            m.launcher().metrics().cold_specializations,
            2,
            "warm batch re-specializes nothing"
        );
        // a 2-image batch splits into two length-1 chunks — the length-1
        // shape is already bound, so still no new specialization
        m.features_batch(&imgs[..2], &thetas).unwrap();
        assert_eq!(m.launcher().metrics().cold_specializations, 2);
        // cache stats confirm the handles bypass the cache: only the
        // bind() calls touched it
        let st = m.launcher().cache_stats();
        assert_eq!(st.misses, 2);
    }

    #[test]
    fn warm_batch_moves_only_images_and_sinograms() {
        let thetas = orientations(5);
        let imgs: Vec<_> = (0..4)
            .map(|i| crate::tracetransform::image::random_phantom(10, 20 + i as u64))
            .collect();
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        m.features_batch(&imgs, &thetas).unwrap(); // cold: builds pipes
        m.launcher().context().memory().unwrap().reset_stats();
        m.features_batch(&imgs, &thetas).unwrap();
        let st = m.launcher().context().mem_stats().unwrap();
        assert_eq!(st.alloc_count, 0, "warm batch allocates nothing");
        assert_eq!(st.h2d_count, 2, "one stacked upload per chunk, no angle re-upload");
        assert_eq!(st.d2h_count, 2, "one sinogram download per chunk");
        // the device-resident skips are visible in the launch metrics
        let lm = m.launcher().metrics();
        assert!(lm.skipped_h2d > 0);
        assert!(lm.skipped_d2h > 0);
    }

    #[test]
    fn emulator_auto_runs_and_caches() {
        let img = shepp_logan(12);
        let thetas = orientations(5);
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        let f1 = m.features(&img, &thetas).unwrap();
        assert_eq!(f1.len(), FEATURE_COUNT);
        let cold = m.launcher().metrics().cold_specializations;
        assert_eq!(cold, 1); // one fused sinogram_all specialization
        // second call: fully warm
        let f2 = m.features(&img, &thetas).unwrap();
        assert_eq!(f1, f2);
        assert_eq!(m.launcher().metrics().cold_specializations, cold);
    }
}
