//! Implementation 5 — "Julia (CPU + GPU)": the full framework. Kernels
//! are launched through the automation layer (`Launcher`, the `@cuda`
//! analog): arguments wrapped `CuIn`/`CuOut`, specialization cached per
//! signature, transfers minimized, module management invisible — the host
//! code shrinks to the paper's Listing 3.

use crate::coordinator::{arg, KernelRegistry, Launcher};
use crate::driver::{BackendKind, Context, LaunchConfig};
use crate::error::Result;
use crate::tensor::Tensor;
use crate::tracetransform::functionals::{reduce_sinogram, T_SET};
use crate::tracetransform::image::Image;
use crate::tracetransform::impls::{register_trace_providers, DeviceChoice, TraceImpl};

/// Which kernel structure the automated path launches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AutoMode {
    /// One fused `sinogram_all` launch per image (the optimized default).
    SinogramAll,
    /// One launch per T-functional (the paper's original 5-kernel
    /// structure; §Perf "before" configuration).
    PerFunctional,
    /// One `trace_full` launch: the whole pipeline, P/F included, on
    /// device (L2 composition; PJRT artifacts only).
    TraceFull,
}

pub struct GpuAuto {
    launcher: Launcher,
    mode: AutoMode,
}

impl GpuAuto {
    pub fn new() -> Result<GpuAuto> {
        Self::on_device(DeviceChoice::Pjrt)
    }

    pub fn on_device(device: DeviceChoice) -> Result<GpuAuto> {
        let launcher = match device {
            DeviceChoice::Pjrt => Launcher::with_default_context()?,
            DeviceChoice::Emulator => {
                let mut l = Launcher::emulator()?;
                register_trace_providers(l.registry_mut());
                l
            }
        };
        Ok(GpuAuto { launcher, mode: AutoMode::SinogramAll })
    }

    pub fn with_mode(mut self, mode: AutoMode) -> Self {
        self.mode = mode;
        self
    }

    /// Single-launch variant using the AOT fused full-pipeline graph.
    pub fn fused() -> Result<GpuAuto> {
        let ctx = Context::default_device()?;
        let registry = KernelRegistry::with_default_library()?;
        Ok(GpuAuto { launcher: Launcher::new(ctx, registry), mode: AutoMode::TraceFull })
    }

    pub fn launcher(&self) -> &Launcher {
        &self.launcher
    }

    pub fn launcher_mut(&mut self) -> &mut Launcher {
        &mut self.launcher
    }
}

impl TraceImpl for GpuAuto {
    fn name(&self) -> &'static str {
        match self.mode {
            AutoMode::SinogramAll => "gpu-auto",
            AutoMode::PerFunctional => "gpu-auto-staged",
            AutoMode::TraceFull => "gpu-auto-fused",
        }
    }

    fn features(&mut self, img: &Image, thetas: &[f32]) -> Result<Vec<f32>> {
        // SLOC:core-begin
        let s = img.size();
        let a = thetas.len();
        let nt = T_SET.len();
        let img_t = img.to_tensor();
        let angles_t = Tensor::from_f32(thetas, &[a]);

        match self.mode {
            AutoMode::TraceFull => {
                // one launch of the L2-fused pipeline
                let mut out =
                    Tensor::zeros_f32(&[crate::tracetransform::functionals::FEATURE_COUNT]);
                self.launcher.launch(
                    "trace_full",
                    LaunchConfig::new(a as u32, s as u32),
                    &mut [arg::cu_in(&img_t), arg::cu_in(&angles_t), arg::cu_out(&mut out)],
                )?;
                Ok(out.to_vec_f32())
            }
            AutoMode::SinogramAll => {
                // @cuda (a, s) sinogram_all(CuIn(img), CuIn(angles), CuOut(sinos))
                let mut sinos = Tensor::zeros_f32(&[nt, a, s]);
                self.launcher.launch(
                    "sinogram_all",
                    LaunchConfig::new(a as u32, s as u32),
                    &mut [arg::cu_in(&img_t), arg::cu_in(&angles_t), arg::cu_out(&mut sinos)],
                )?;
                let all = sinos.as_f32();
                let mut feats = Vec::with_capacity(nt * 6);
                for ti in 0..nt {
                    feats.extend(reduce_sinogram(&all[ti * a * s..(ti + 1) * a * s], a, s));
                }
                Ok(feats)
            }
            AutoMode::PerFunctional => {
                // the paper's structure: one kernel per T-functional,
                // @cuda (a, s) sinogram_t(CuIn(img), CuIn(angles), CuOut(sino))
                let mut feats = Vec::with_capacity(nt * 6);
                let mut sino = Tensor::zeros_f32(&[a, s]);
                for t in T_SET {
                    self.launcher.launch(
                        &format!("sinogram_{}", t.name()),
                        LaunchConfig::new(a as u32, s as u32),
                        &mut [
                            arg::cu_in(&img_t),
                            arg::cu_in(&angles_t),
                            arg::cu_out(&mut sino),
                        ],
                    )?;
                    feats.extend(reduce_sinogram(sino.as_f32(), a, s));
                }
                Ok(feats)
            }
        }
        // SLOC:core-end
    }

    /// Batched path: one `batched_sinogram` launch covers the whole
    /// batch — the angle table and the stacked images upload once, and
    /// every subsequent batch reuses the specialization's pre-allocated
    /// device buffers (no allocator traffic at steady state).
    fn features_batch(&mut self, imgs: &[Image], thetas: &[f32]) -> Result<Vec<Vec<f32>>> {
        if imgs.is_empty() {
            return Ok(Vec::new());
        }
        let batched_ok = self.mode == AutoMode::SinogramAll
            && self.launcher.context().device().kind == BackendKind::VtxEmulator
            && imgs.iter().all(|i| i.size() == imgs[0].size());
        if !batched_ok {
            // PJRT artifacts and the ablation modes have no batched
            // lowering — sequential fallback
            return imgs.iter().map(|img| self.features(img, thetas)).collect();
        }
        let s = imgs[0].size();
        let n = imgs.len();
        let a = thetas.len();
        let nt = T_SET.len();
        let mut stacked = Vec::with_capacity(n * s * s);
        for img in imgs {
            stacked.extend_from_slice(img.pixels());
        }
        let imgs_t = Tensor::from_f32(&stacked, &[n, s, s]);
        let angles_t = Tensor::from_f32(thetas, &[a]);
        let mut sinos = Tensor::zeros_f32(&[n, nt, a, s]);
        self.launcher.launch(
            "batched_sinogram",
            LaunchConfig::new((a as u32, n as u32), s as u32),
            &mut [arg::cu_in(&imgs_t), arg::cu_in(&angles_t), arg::cu_out(&mut sinos)],
        )?;
        let all = sinos.as_f32();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let mut feats = Vec::with_capacity(nt * 6);
            for ti in 0..nt {
                let off = (i * nt + ti) * a * s;
                feats.extend(reduce_sinogram(&all[off..off + a * s], a, s));
            }
            out.push(feats);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracetransform::functionals::FEATURE_COUNT;
    use crate::tracetransform::image::{orientations, shepp_logan};

    #[test]
    fn batched_path_specializes_once_per_batch_shape() {
        let thetas = orientations(5);
        let imgs: Vec<_> = (0..3)
            .map(|i| crate::tracetransform::image::random_phantom(10, i as u64))
            .collect();
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        let b1 = m.features_batch(&imgs, &thetas).unwrap();
        assert_eq!(m.launcher().metrics().cold_specializations, 1);
        let b2 = m.features_batch(&imgs, &thetas).unwrap();
        assert_eq!(b1, b2);
        assert_eq!(m.launcher().metrics().cold_specializations, 1, "warm batch");
        // a different batch size is a different signature
        m.features_batch(&imgs[..2], &thetas).unwrap();
        assert_eq!(m.launcher().metrics().cold_specializations, 2);
    }

    #[test]
    fn emulator_auto_runs_and_caches() {
        let img = shepp_logan(12);
        let thetas = orientations(5);
        let mut m = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
        let f1 = m.features(&img, &thetas).unwrap();
        assert_eq!(f1.len(), FEATURE_COUNT);
        let cold = m.launcher().metrics().cold_specializations;
        assert_eq!(cold, 1); // one fused sinogram_all specialization
        // second call: fully warm
        let f2 = m.features(&img, &thetas).unwrap();
        assert_eq!(f1, f2);
        assert_eq!(m.launcher().metrics().cold_specializations, cold);
    }
}
