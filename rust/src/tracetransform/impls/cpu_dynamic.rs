//! Implementation 3 — "Julia (CPU)": the same algorithm written against
//! the dynamic `hostlang` layer. Every pixel access is bounds-checked and
//! 1-indexed, every value boxed (f64), every arithmetic dispatch dynamic —
//! reproducing, by construction, the checks the paper blames for the
//! Julia-vs-C++ CPU gap (§7.3: "unnecessary checks on integer conversions
//! and array bounds").

use crate::error::Result;
use crate::hostlang::{DynArray, Value};
use crate::tracetransform::functionals::{FFunctional, PFunctional, TFunctional, F_SET, P_SET, T_SET};
use crate::tracetransform::image::Image;
use crate::tracetransform::impls::TraceImpl;

pub struct CpuDynamic;

impl CpuDynamic {
    pub fn new() -> CpuDynamic {
        CpuDynamic
    }
}

impl Default for CpuDynamic {
    fn default() -> Self {
        Self::new()
    }
}

/// Bilinear sample via dynamic, 1-indexed, bounds-checked access.
fn sample_dyn(img: &DynArray, s: usize, sy: f64, sx: f64) -> Result<f64> {
    let y0 = sy.floor();
    let x0 = sx.floor();
    let fy = sy - y0;
    let fx = sx - x0;
    // 1-indexed coordinates of the four neighbours
    let gather = |yi: i64, xi: i64| -> Result<f64> {
        if yi >= 0 && (yi as usize) < s && xi >= 0 && (xi as usize) < s {
            // hostlang is 1-indexed: +1 (the conversion the paper's
            // intrinsics perform for Julia convention, §5)
            img.get(&[yi as usize + 1, xi as usize + 1])?.as_float()
        } else {
            Ok(0.0)
        }
    };
    let (y0i, x0i) = (y0 as i64, x0 as i64);
    Ok(gather(y0i, x0i)? * (1.0 - fy) * (1.0 - fx)
        + gather(y0i, x0i + 1)? * (1.0 - fy) * fx
        + gather(y0i + 1, x0i)? * fy * (1.0 - fx)
        + gather(y0i + 1, x0i + 1)? * fy * fx)
}

impl CpuDynamic {
    /// Core staged pipeline against a precomputed `(sin, cos)` table —
    /// the batched path shares one table across all images.
    fn features_with_trig(&self, img: &Image, trig: &[(f64, f64)]) -> Result<Vec<f32>> {
        // SLOC:core-begin
        let s = img.size();
        let a = trig.len();
        // host data lives in boxed f64 arrays (the dynamic language world)
        let dimg = DynArray::from_f32(img.pixels(), &[s, s])?;
        let c = (s as f64 - 1.0) / 2.0;

        // staged: materialize each rotation, then apply every T-functional
        let sinos: Vec<DynArray> =
            T_SET.iter().map(|_| DynArray::zeros(&[a, s])).collect();
        for (ai, &(st, ct)) in trig.iter().enumerate() {
            let rot = DynArray::zeros(&[s, s]);
            for y in 1..=s {
                for x in 1..=s {
                    let dx = (x - 1) as f64 - c;
                    let dy = (y - 1) as f64 - c;
                    let sx = ct * dx + st * dy + c;
                    let sy = -st * dx + ct * dy + c;
                    let v = sample_dyn(&dimg, s, sy, sx)?;
                    rot.set(&[y, x], &Value::Float(v))?;
                }
            }
            for (ti, t) in T_SET.iter().enumerate() {
                for x in 1..=s {
                    let mut acc = match t {
                        TFunctional::TMax => f64::NEG_INFINITY,
                        _ => 0.0,
                    };
                    for y in 1..=s {
                        let v = rot.get(&[y, x])?.as_float()?;
                        let dy = (y - 1) as f64 - c;
                        match t {
                            TFunctional::Radon => acc += v,
                            TFunctional::T1 => acc += dy.abs() * v,
                            TFunctional::T2 => acc += dy * dy * v,
                            TFunctional::TMax => acc = acc.max(v),
                        }
                    }
                    sinos[ti].set(&[ai + 1, x], &Value::Float(acc))?;
                }
            }
        }

        // P/F stacks, still dynamic
        let mut feats = Vec::new();
        for sino in &sinos {
            for p in P_SET {
                let mut circus = Vec::with_capacity(a);
                for ai in 1..=a {
                    let mut acc = match p {
                        PFunctional::Max => f64::NEG_INFINITY,
                        _ => 0.0,
                    };
                    for x in 1..=s {
                        let v = sino.get(&[ai, x])?.as_float()?;
                        match p {
                            PFunctional::Sum => acc += v,
                            PFunctional::Max => acc = acc.max(v),
                            PFunctional::L1 => acc += v.abs(),
                        }
                    }
                    circus.push(acc);
                }
                for f in F_SET {
                    let v = match f {
                        FFunctional::Mean => circus.iter().sum::<f64>() / a as f64,
                        FFunctional::Max => {
                            circus.iter().copied().fold(f64::NEG_INFINITY, f64::max)
                        }
                    };
                    feats.push(v as f32);
                }
            }
        }
        // SLOC:core-end
        Ok(feats)
    }
}

impl TraceImpl for CpuDynamic {
    fn name(&self) -> &'static str {
        "cpu-dynamic"
    }

    fn features(&mut self, img: &Image, thetas: &[f32]) -> Result<Vec<f32>> {
        let trig: Vec<(f64, f64)> =
            thetas.iter().map(|&t| (t as f64).sin_cos()).collect();
        self.features_with_trig(img, &trig)
    }

    /// Batched path: the boxed trig table converts once per batch instead
    /// of once per image.
    fn features_batch(&mut self, imgs: &[Image], thetas: &[f32]) -> Result<Vec<Vec<f32>>> {
        let trig: Vec<(f64, f64)> =
            thetas.iter().map(|&t| (t as f64).sin_cos()).collect();
        imgs.iter().map(|img| self.features_with_trig(img, &trig)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracetransform::functionals::FEATURE_COUNT;
    use crate::tracetransform::image::{orientations, shepp_logan};

    #[test]
    fn produces_full_feature_vector() {
        let img = shepp_logan(12);
        let feats = CpuDynamic::new()
            .features(&img, &orientations(5))
            .unwrap();
        assert_eq!(feats.len(), FEATURE_COUNT);
        assert!(feats.iter().all(|f| f.is_finite()));
    }

    #[test]
    fn dynamic_sampling_matches_native_sampling() {
        let img = shepp_logan(16);
        let d = DynArray::from_f32(img.pixels(), &[16, 16]).unwrap();
        for &(sy, sx) in &[(3.25f64, 7.5f64), (0.0, 0.0), (14.9, 2.1), (-1.0, 5.0)] {
            let got = sample_dyn(&d, 16, sy, sx).unwrap();
            let want =
                crate::tracetransform::rotate::sample_bilinear(img.pixels(), 16, sy as f32, sx as f32);
            assert!((got - want as f64).abs() < 1e-5, "({sy},{sx}): {got} vs {want}");
        }
    }
}
