//! Native bilinear rotation and the fused rotate+project sinogram step.
//!
//! The rotation convention is shared **exactly** with
//! `python/compile/kernels/rotate.py` and the VTX `rotate` kernel:
//!
//! ```text
//! c = (S-1)/2;  dx = x - c;  dy = y - c
//! sx =  cosθ·dx + sinθ·dy + c
//! sy = −sinθ·dx + cosθ·dy + c
//! out[y, x] = bilinear(img, sy, sx)   (zero outside)
//! ```

use crate::tracetransform::functionals::TFunctional;
use crate::tracetransform::image::Image;

/// Bilinear sample with zero fill.
#[inline]
pub fn sample_bilinear(img: &[f32], s: usize, sy: f32, sx: f32) -> f32 {
    let y0f = sy.floor();
    let x0f = sx.floor();
    let fy = sy - y0f;
    let fx = sx - x0f;
    let y0 = y0f as i64;
    let x0 = x0f as i64;
    #[inline]
    fn gather(img: &[f32], s: usize, yi: i64, xi: i64) -> f32 {
        if yi >= 0 && (yi as usize) < s && xi >= 0 && (xi as usize) < s {
            img[yi as usize * s + xi as usize]
        } else {
            0.0
        }
    }
    gather(img, s, y0, x0) * (1.0 - fy) * (1.0 - fx)
        + gather(img, s, y0, x0 + 1) * (1.0 - fy) * fx
        + gather(img, s, y0 + 1, x0) * fy * (1.0 - fx)
        + gather(img, s, y0 + 1, x0 + 1) * fy * fx
}

/// Rotate an image by `theta` radians (materializes the rotated image).
pub fn rotate(img: &Image, theta: f32) -> Image {
    let s = img.size();
    let c = (s as f32 - 1.0) / 2.0;
    let (st, ct) = theta.sin_cos();
    let src = img.pixels();
    let mut out = Image::zeros(s);
    let dst = out.pixels_mut();
    for y in 0..s {
        let dy = y as f32 - c;
        for x in 0..s {
            let dx = x as f32 - c;
            let sx = ct * dx + st * dy + c;
            let sy = -st * dx + ct * dy + c;
            dst[y * s + x] = sample_bilinear(src, s, sy, sx);
        }
    }
    out
}

/// One sinogram row: T-functional of the virtually rotated image, per
/// column — fused, never materializing the rotation (the optimized native
/// path; mirrors the Pallas `sinogram` kernel and the VTX version).
pub fn sinogram_row(img: &Image, theta: f32, t: TFunctional, out_row: &mut [f32]) {
    let s = img.size();
    debug_assert_eq!(out_row.len(), s);
    let c = (s as f32 - 1.0) / 2.0;
    let (st, ct) = theta.sin_cos();
    let src = img.pixels();
    for (col, out) in out_row.iter_mut().enumerate() {
        let dx = col as f32 - c;
        let sx_base = ct * dx + c;
        let sy_base = c - st * dx;
        let mut acc = match t {
            TFunctional::TMax => f32::NEG_INFINITY,
            _ => 0.0,
        };
        for r in 0..s {
            let dy = r as f32 - c;
            let sx = sx_base + st * dy;
            let sy = sy_base + ct * dy;
            let v = sample_bilinear(src, s, sy, sx);
            match t {
                TFunctional::Radon => acc += v,
                TFunctional::T1 => acc += dy.abs() * v,
                TFunctional::T2 => acc += dy * dy * v,
                TFunctional::TMax => acc = acc.max(v),
            }
        }
        *out = acc;
    }
}

/// Full sinogram: `thetas.len()` rows × `size` offsets, row-major.
pub fn sinogram(img: &Image, thetas: &[f32], t: TFunctional) -> Vec<f32> {
    let s = img.size();
    let mut out = vec![0.0f32; thetas.len() * s];
    for (a, &theta) in thetas.iter().enumerate() {
        sinogram_row(img, theta, t, &mut out[a * s..(a + 1) * s]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracetransform::image::shepp_logan;

    #[test]
    fn zero_rotation_is_identity() {
        let img = shepp_logan(24);
        let r = rotate(&img, 0.0);
        for (a, b) in img.pixels().iter().zip(r.pixels()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn quarter_turn_four_times_is_near_identity() {
        let img = shepp_logan(33);
        let mut r = img.clone();
        for _ in 0..4 {
            r = rotate(&r, std::f32::consts::FRAC_PI_2);
        }
        // center region should be close (edges lose mass)
        let s = img.size();
        for y in s / 4..3 * s / 4 {
            for x in s / 4..3 * s / 4 {
                assert!(
                    (img.get(y, x) - r.get(y, x)).abs() < 0.05,
                    "pixel ({y},{x})"
                );
            }
        }
    }

    #[test]
    fn fused_sinogram_matches_staged() {
        let img = shepp_logan(32);
        let thetas = [0.0f32, 0.4, 1.1, 2.7];
        for t in crate::tracetransform::functionals::T_SET {
            let fused = sinogram(&img, &thetas, t);
            for (a, &theta) in thetas.iter().enumerate() {
                let rot = rotate(&img, theta);
                for col in 0..32 {
                    let staged = t.apply_strided(&rot.pixels()[col..], 32, 32);
                    let f = fused[a * 32 + col];
                    assert!(
                        (f - staged).abs() < 1e-3,
                        "{t:?} angle {a} col {col}: {f} vs {staged}"
                    );
                }
            }
        }
    }

    #[test]
    fn radon_preserves_total_mass_at_zero_angle() {
        let img = shepp_logan(32);
        let sino = sinogram(&img, &[0.0], TFunctional::Radon);
        let total: f32 = sino.iter().sum();
        let mass: f32 = img.pixels().iter().sum();
        assert!((total - mass).abs() / mass < 1e-4);
    }
}
