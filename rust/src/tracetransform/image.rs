//! Images: storage, synthetic phantom generation and PGM I/O.
//!
//! The paper's benchmark inputs are grayscale images of varying sizes
//! (§7.3 sweeps the input size). We generate Shepp-Logan-style ellipse
//! phantoms deterministically so every implementation sees identical
//! pixels, and support binary PGM (P5) for external images.

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::Tensor;
use crate::util::Prng;

/// A square grayscale image, f32 pixels in [0, 1], row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Image {
    size: usize,
    data: Vec<f32>,
}

impl Image {
    pub fn new(size: usize, data: Vec<f32>) -> Result<Image> {
        if data.len() != size * size {
            return Err(Error::Type(format!(
                "image data length {} != {size}x{size}",
                data.len()
            )));
        }
        Ok(Image { size, data })
    }

    pub fn zeros(size: usize) -> Image {
        Image { size, data: vec![0.0; size * size] }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn pixels(&self) -> &[f32] {
        &self.data
    }

    pub fn pixels_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn get(&self, row: usize, col: usize) -> f32 {
        self.data[row * self.size + col]
    }

    pub fn set(&mut self, row: usize, col: usize, v: f32) {
        self.data[row * self.size + col] = v;
    }

    pub fn to_tensor(&self) -> Tensor {
        Tensor::from_f32(&self.data, &[self.size, self.size])
    }

    pub fn from_tensor(t: &Tensor) -> Result<Image> {
        let shape = t.shape();
        if shape.len() != 2 || shape[0] != shape[1] {
            return Err(Error::Type(format!(
                "expected square 2-d tensor, got {}",
                t.signature()
            )));
        }
        Image::new(shape[0], t.as_f32().to_vec())
    }

    /// Mean pixel intensity (used by sanity checks).
    pub fn mean(&self) -> f32 {
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    // ---- PGM (P5) I/O ----------------------------------------------------

    pub fn write_pgm(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        write!(f, "P5\n{} {}\n255\n", self.size, self.size)?;
        let bytes: Vec<u8> = self
            .data
            .iter()
            .map(|&v| (v.clamp(0.0, 1.0) * 255.0).round() as u8)
            .collect();
        f.write_all(&bytes)?;
        Ok(())
    }

    pub fn read_pgm(path: impl AsRef<Path>) -> Result<Image> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        Self::parse_pgm(&bytes)
    }

    pub fn parse_pgm(bytes: &[u8]) -> Result<Image> {
        let bad = |m: &str| Error::Other(format!("PGM parse error: {m}"));
        // header: magic, width, height, maxval — whitespace/comment separated
        let mut pos = 0usize;
        let mut token = |bytes: &[u8]| -> Result<String> {
            // skip whitespace and comments
            loop {
                while pos < bytes.len() && bytes[pos].is_ascii_whitespace() {
                    pos += 1;
                }
                if pos < bytes.len() && bytes[pos] == b'#' {
                    while pos < bytes.len() && bytes[pos] != b'\n' {
                        pos += 1;
                    }
                } else {
                    break;
                }
            }
            let start = pos;
            while pos < bytes.len() && !bytes[pos].is_ascii_whitespace() {
                pos += 1;
            }
            if start == pos {
                return Err(bad("unexpected EOF in header"));
            }
            Ok(String::from_utf8_lossy(&bytes[start..pos]).into_owned())
        };
        if token(bytes)? != "P5" {
            return Err(bad("not a binary PGM (P5)"));
        }
        let w: usize = token(bytes)?.parse().map_err(|_| bad("bad width"))?;
        let h: usize = token(bytes)?.parse().map_err(|_| bad("bad height"))?;
        let maxval: usize = token(bytes)?.parse().map_err(|_| bad("bad maxval"))?;
        if w != h {
            return Err(bad("only square images supported"));
        }
        if maxval == 0 || maxval > 255 {
            return Err(bad("unsupported maxval"));
        }
        pos += 1; // single whitespace after maxval
        let need = w * h;
        if bytes.len() < pos + need {
            return Err(bad("truncated pixel data"));
        }
        let data: Vec<f32> = bytes[pos..pos + need]
            .iter()
            .map(|&b| b as f32 / maxval as f32)
            .collect();
        Image::new(w, data)
    }
}

/// One ellipse of a phantom: center (fractions of the image), semi-axes,
/// rotation and additive intensity.
#[derive(Clone, Copy, Debug)]
pub struct Ellipse {
    pub cx: f32,
    pub cy: f32,
    pub a: f32,
    pub b: f32,
    pub angle: f32,
    pub intensity: f32,
}

/// Render ellipses into an image (additive, clamped at the end).
pub fn render_phantom(size: usize, ellipses: &[Ellipse]) -> Image {
    let mut img = Image::zeros(size);
    let s = size as f32;
    for row in 0..size {
        for col in 0..size {
            let x = (col as f32 + 0.5) / s - 0.5;
            let y = (row as f32 + 0.5) / s - 0.5;
            let mut v = 0.0f32;
            for e in ellipses {
                let dx = x - e.cx;
                let dy = y - e.cy;
                let (sa, ca) = e.angle.sin_cos();
                let u = ca * dx + sa * dy;
                let w = -sa * dx + ca * dy;
                if (u / e.a) * (u / e.a) + (w / e.b) * (w / e.b) <= 1.0 {
                    v += e.intensity;
                }
            }
            img.set(row, col, v.clamp(0.0, 1.0));
        }
    }
    img
}

/// The standard head-phantom-like test image used by the benchmarks.
pub fn shepp_logan(size: usize) -> Image {
    render_phantom(
        size,
        &[
            Ellipse { cx: 0.0, cy: 0.0, a: 0.345, b: 0.46, angle: 0.0, intensity: 0.8 },
            Ellipse { cx: 0.0, cy: -0.0092, a: 0.331, b: 0.437, angle: 0.0, intensity: -0.3 },
            Ellipse { cx: 0.11, cy: 0.0, a: 0.055, b: 0.155, angle: -0.31, intensity: -0.2 },
            Ellipse { cx: -0.11, cy: 0.0, a: 0.08, b: 0.205, angle: 0.31, intensity: -0.2 },
            Ellipse { cx: 0.0, cy: 0.175, a: 0.105, b: 0.125, angle: 0.0, intensity: 0.15 },
            Ellipse { cx: 0.0, cy: 0.05, a: 0.023, b: 0.023, angle: 0.0, intensity: 0.15 },
            Ellipse { cx: 0.0, cy: -0.053, a: 0.023, b: 0.023, angle: 0.0, intensity: 0.15 },
            Ellipse { cx: -0.04, cy: -0.303, a: 0.029, b: 0.011, angle: 0.0, intensity: 0.15 },
            Ellipse { cx: 0.03, cy: -0.303, a: 0.011, b: 0.011, angle: 0.0, intensity: 0.15 },
            Ellipse { cx: 0.03, cy: 0.303, a: 0.011, b: 0.023, angle: 0.0, intensity: 0.15 },
        ],
    )
}

/// A deterministic random phantom (corpus generation for the E2E driver).
pub fn random_phantom(size: usize, seed: u64) -> Image {
    let mut rng = Prng::new(seed);
    let n = rng.usize_in(3, 7);
    let ellipses: Vec<Ellipse> = (0..n)
        .map(|_| Ellipse {
            cx: rng.f32_in(-0.25, 0.25),
            cy: rng.f32_in(-0.25, 0.25),
            a: rng.f32_in(0.04, 0.3),
            b: rng.f32_in(0.04, 0.3),
            angle: rng.f32_in(0.0, std::f32::consts::PI),
            intensity: rng.f32_in(0.1, 0.5),
        })
        .collect();
    render_phantom(size, &ellipses)
}

/// Orientation set: `n` angles uniform over [0, π).
pub fn orientations(n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| i as f32 * std::f32::consts::PI / n as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phantom_deterministic_and_bounded() {
        let a = shepp_logan(64);
        let b = shepp_logan(64);
        assert_eq!(a, b);
        assert!(a.pixels().iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(a.mean() > 0.05, "phantom should have content: {}", a.mean());
    }

    #[test]
    fn random_phantoms_differ_by_seed() {
        let a = random_phantom(32, 1);
        let b = random_phantom(32, 2);
        assert_ne!(a, b);
        assert_eq!(a, random_phantom(32, 1));
    }

    #[test]
    fn pgm_roundtrip() {
        let img = shepp_logan(32);
        let dir = std::env::temp_dir().join("hlgpu_test_pgm");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("phantom.pgm");
        img.write_pgm(&path).unwrap();
        let back = Image::read_pgm(&path).unwrap();
        assert_eq!(back.size(), 32);
        // 8-bit quantization: within 1/255
        for (a, b) in img.pixels().iter().zip(back.pixels()) {
            assert!((a - b).abs() <= 1.0 / 255.0 + 1e-6);
        }
    }

    #[test]
    fn pgm_parses_comments() {
        let mut bytes = b"P5\n# a comment\n2 2\n255\n".to_vec();
        bytes.extend_from_slice(&[0, 128, 255, 64]);
        let img = Image::parse_pgm(&bytes).unwrap();
        assert_eq!(img.size(), 2);
        assert!((img.get(0, 1) - 128.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn pgm_rejects_truncated() {
        let bytes = b"P5\n4 4\n255\n\x00\x01".to_vec();
        assert!(Image::parse_pgm(&bytes).is_err());
    }

    #[test]
    fn orientations_cover_half_turn() {
        let o = orientations(90);
        assert_eq!(o.len(), 90);
        assert_eq!(o[0], 0.0);
        assert!(o[89] < std::f32::consts::PI);
    }

    #[test]
    fn tensor_roundtrip() {
        let img = shepp_logan(16);
        let t = img.to_tensor();
        assert_eq!(t.shape(), &[16, 16]);
        assert_eq!(Image::from_tensor(&t).unwrap(), img);
    }
}
