//! Differential test harness for the parallel block scheduler and the
//! tiered execution engine.
//!
//! Three claims are proven here:
//!
//! 1. **Numerical equivalence across implementations**: the emulator-path
//!    trace-transform implementations (`gpu-manual`, `gpu-dynamic`,
//!    `gpu-auto` — all ultimately executing VTX kernels through the
//!    parallel scheduler) agree element-wise with the native CPU
//!    reference across multiple image sizes and PRNG seeds.
//! 2. **Schedule equivalence**: the parallel schedule is observationally
//!    identical to the sequential one — bitwise-equal kernel results for
//!    every pool width, and *identical trap coordinates and reasons* for
//!    every trap class (OOB access, barrier divergence, step-budget
//!    exhaustion).
//! 3. **Tier equivalence**: the warp-vectorized tier (basic-block
//!    lowering + superinstruction fusion) and the compiled tier
//!    (closure-JIT block bodies with tier-up and deopt) are
//!    observationally identical to the scalar reference tier —
//!    bitwise-equal results and identical trap coordinates/reasons
//!    across every (tier, schedule width, tier-up threshold)
//!    combination, and a deopt leaves exactly the state the vector
//!    tier would have produced, bitwise.

use hlgpu::emulator::{
    execute_with, execute_with_tier, set_default_tier_up, ExecTier, KernelBuilder, Launch, Limits,
    ScalarArg,
};
use std::sync::{Mutex, MutexGuard};
use hlgpu::error::Error;
use hlgpu::tracetransform::{
    orientations, random_phantom, shepp_logan, CpuNative, DeviceChoice, GpuAuto, GpuDynamic,
    GpuManual, TraceImpl, FEATURE_COUNT,
};

/// The tier-up override is process-global, so every compiled-tier run
/// in this binary scopes it through this lock (restored on drop, even
/// across a failing assertion).
static TIER_UP_LOCK: Mutex<()> = Mutex::new(());

struct TierUpGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for TierUpGuard {
    fn drop(&mut self) {
        set_default_tier_up(None);
    }
}

/// Pin the tier-up threshold for the duration of the returned guard:
/// `0` = compile every block on first entry, `n` = tier up mid-run
/// after `n` vector executions.
fn force_tier_up(threshold: u64) -> TierUpGuard {
    let g = TIER_UP_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    set_default_tier_up(Some(threshold));
    TierUpGuard(g)
}

/// The tier flavors every cross-tier test runs: the scalar reference,
/// the vector tier, the compiled tier with every block force-compiled
/// on first entry, and the compiled tier tiering up mid-run.
const TIER_FLAVORS: [(ExecTier, Option<u64>); 4] = [
    (ExecTier::Scalar, None),
    (ExecTier::Vector, None),
    (ExecTier::Compiled, Some(0)),
    (ExecTier::Compiled, Some(2)),
];

fn assert_close(name: &str, got: &[f32], want: &[f32], rel: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= rel * w.abs().max(1.0),
            "{name}: feature {i}: {g} vs {w}"
        );
    }
}

// ---------------------------------------------------------------- part 1 --

#[test]
fn emulator_impls_match_cpu_native_across_sizes_and_seeds() {
    let thetas = orientations(8);
    for &size in &[12usize, 16, 24] {
        for seed in 0..3u64 {
            let img = random_phantom(size, 100 + seed);
            let want = CpuNative::new().features(&img, &thetas).unwrap();
            assert_eq!(want.len(), FEATURE_COUNT);

            let manual = GpuManual::on_device(DeviceChoice::Emulator)
                .unwrap()
                .features(&img, &thetas)
                .unwrap();
            assert_close(&format!("gpu-manual s={size} seed={seed}"), &manual, &want, 2e-3);

            let dynamic = GpuDynamic::on_device(DeviceChoice::Emulator)
                .unwrap()
                .features(&img, &thetas)
                .unwrap();
            assert_close(&format!("gpu-dynamic s={size} seed={seed}"), &dynamic, &want, 2e-3);

            let auto = GpuAuto::on_device(DeviceChoice::Emulator)
                .unwrap()
                .features(&img, &thetas)
                .unwrap();
            assert_close(&format!("gpu-auto s={size} seed={seed}"), &auto, &want, 2e-3);
        }
    }
}

#[test]
fn shepp_logan_differential_at_multiple_sizes() {
    let thetas = orientations(10);
    for &size in &[16usize, 20] {
        let img = shepp_logan(size);
        let want = CpuNative::new().features(&img, &thetas).unwrap();
        let auto = GpuAuto::on_device(DeviceChoice::Emulator)
            .unwrap()
            .features(&img, &thetas)
            .unwrap();
        assert_close(&format!("gpu-auto shepp-logan s={size}"), &auto, &want, 2e-3);
    }
}

// ---------------------------------------------------------------- part 2 --

/// vadd without a tail guard: OOB as soon as a thread's global index
/// reaches past the (undersized) buffers.
fn unguarded_vadd() -> hlgpu::emulator::Kernel {
    let mut b = KernelBuilder::new("vadd_unguarded");
    let pa = b.ptr_param();
    let pb = b.ptr_param();
    let pc = b.ptr_param();
    let tid = b.tid_x();
    let bid = b.ctaid_x();
    let bdim = b.ntid_x();
    let base = b.imul(bid, bdim);
    let gid = b.iadd(base, tid);
    let x = b.ldg(pa, gid);
    let y = b.ldg(pb, gid);
    let s = b.fadd(x, y);
    b.stg(pc, gid, s);
    b.ret();
    b.build().unwrap()
}

/// Run the same launch under both schedules and return both errors.
fn trap_under_both_schedules(
    k: &hlgpu::emulator::Kernel,
    grid: (u32, u32),
    block: (u32, u32),
    buf_len: usize,
    nbufs: usize,
    limits: Limits,
) -> (Error, Error) {
    let mut run = |workers: usize| -> Error {
        let mut bufs: Vec<Vec<f32>> = (0..nbufs).map(|_| vec![1.0f32; buf_len]).collect();
        let views: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
        execute_with(
            Launch {
                kernel: k,
                grid,
                block,
                buffers: views,
                scalars: vec![],
                limits,
            },
            workers,
        )
        .unwrap_err()
    };
    (run(1), run(8))
}

fn assert_same_trap(seq: &Error, par: &Error) {
    match (seq, par) {
        (
            Error::VtxTrap { kernel: k1, block: b1, thread: t1, reason: r1 },
            Error::VtxTrap { kernel: k2, block: b2, thread: t2, reason: r2 },
        ) => {
            assert_eq!(k1, k2, "kernel name");
            assert_eq!(b1, b2, "block coordinates");
            assert_eq!(t1, t2, "thread coordinates");
            assert_eq!(r1, r2, "trap reason");
        }
        other => panic!("expected two VtxTrap errors, got {other:?}"),
    }
}

#[test]
fn oob_trap_identical_under_parallel_schedule() {
    let k = unguarded_vadd();
    // 8 blocks x 16 threads = 128 global ids, buffers of 40 elements:
    // the first OOB thread the sequential schedule meets is block 2,
    // thread 8 (gid 40). The parallel schedule must report the same one.
    let (seq, par) = trap_under_both_schedules(&k, (8, 1), (16, 1), 40, 3, Limits::default());
    assert_same_trap(&seq, &par);
    if let Error::VtxTrap { block, thread, reason, .. } = &seq {
        assert_eq!(*block, (2, 0, 0));
        assert_eq!(*thread, (8, 0, 0));
        assert!(reason.contains("OOB"), "{reason}");
    }
}

#[test]
fn barrier_divergence_trap_identical_under_parallel_schedule() {
    // threads with tid==0 exit before the barrier in EVERY block; the
    // reported divergence must come from block (0,0) under both
    // schedules (lowest block index wins).
    let mut b = KernelBuilder::new("diverge_all_blocks");
    let tid = b.tid_x();
    let zero = b.consti(0);
    let is0 = b.cmpi(hlgpu::emulator::isa::CmpOp::Eq, tid, zero);
    let out = b.label();
    b.bra_if(is0, out);
    b.bar();
    b.bind(out);
    b.ret();
    let k = b.build().unwrap();
    let (seq, par) = trap_under_both_schedules(&k, (6, 1), (4, 1), 0, 0, Limits::default());
    assert_same_trap(&seq, &par);
    if let Error::VtxTrap { block, reason, .. } = &seq {
        assert_eq!(*block, (0, 0, 0));
        assert!(reason.contains("barrier divergence"), "{reason}");
    }
}

#[test]
fn step_budget_trap_identical_under_parallel_schedule() {
    // every thread of every block spins; the reported exhaustion must be
    // block (0,0), thread (0,0) under both schedules.
    let mut b = KernelBuilder::new("spin_grid");
    let top = b.label();
    b.bind(top);
    b.bra(top);
    let k = b.build().unwrap();
    let (seq, par) = trap_under_both_schedules(
        &k,
        (4, 1),
        (2, 1),
        0,
        0,
        Limits { steps_per_thread: 500 },
    );
    assert_same_trap(&seq, &par);
    if let Error::VtxTrap { block, thread, reason, .. } = &seq {
        assert_eq!(*block, (0, 0, 0));
        assert_eq!(*thread, (0, 0, 0));
        assert!(reason.contains("step budget"), "{reason}");
    }
}

// ---------------------------------------------------------------- part 3 --

/// Run the same launch under every tier flavor (scalar, vector,
/// force-compiled, mid-run tier-up), assert every trap is identical to
/// the scalar reference's, and return that trap for field assertions.
fn trap_under_all_tiers(
    k: &hlgpu::emulator::Kernel,
    grid: (u32, u32),
    block: (u32, u32),
    buf_len: usize,
    nbufs: usize,
    limits: Limits,
) -> Error {
    let mut run = |tier: ExecTier, threshold: Option<u64>| -> Error {
        let _g = threshold.map(force_tier_up);
        let mut bufs: Vec<Vec<f32>> = (0..nbufs).map(|_| vec![1.0f32; buf_len]).collect();
        let views: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
        execute_with_tier(
            Launch { kernel: k, grid, block, buffers: views, scalars: vec![], limits },
            1,
            tier,
        )
        .unwrap_err()
    };
    let scalar = run(ExecTier::Scalar, None);
    for (tier, threshold) in TIER_FLAVORS.into_iter().skip(1) {
        let got = run(tier, threshold);
        assert_same_trap(&scalar, &got);
    }
    scalar
}

#[test]
fn oob_trap_identical_across_tiers() {
    let k = unguarded_vadd();
    // Same geometry as the schedule test: the first OOB thread the
    // scalar tier meets is block 2, thread 8 — the vector and compiled
    // tiers must report exactly that lane even though they discover
    // the trap in lockstep (the compiled tier via a bounds-guard deopt
    // onto the vector op path).
    let scalar = trap_under_all_tiers(&k, (8, 1), (16, 1), 40, 3, Limits::default());
    if let Error::VtxTrap { block, thread, reason, .. } = &scalar {
        assert_eq!(*block, (2, 0, 0));
        assert_eq!(*thread, (8, 0, 0));
        assert!(reason.contains("OOB"), "{reason}");
    }
}

#[test]
fn step_budget_trap_identical_across_tiers() {
    let mut b = KernelBuilder::new("spin_tiers");
    let top = b.label();
    b.bind(top);
    b.bra(top);
    let k = b.build().unwrap();
    let scalar = trap_under_all_tiers(
        &k,
        (2, 1),
        (4, 1),
        0,
        0,
        Limits { steps_per_thread: 333 },
    );
    if let Error::VtxTrap { block, thread, reason, .. } = &scalar {
        assert_eq!(*block, (0, 0, 0));
        assert_eq!(*thread, (0, 0, 0));
        assert!(reason.contains("step budget exhausted (333"), "{reason}");
    }
}

#[test]
fn divergence_trap_reports_waiting_thread_coordinates_on_both_tiers() {
    // Regression for the hardcoded (0, 0) divergence report: thread 0
    // exits early, threads 1..4 wait at the barrier — the trap must name
    // thread (1, 0, 0), the lowest ACTUALLY waiting thread, on both
    // tiers.
    let mut b = KernelBuilder::new("diverge_nonzero_waiter");
    let tid = b.tid_x();
    let zero = b.consti(0);
    let is0 = b.cmpi(hlgpu::emulator::isa::CmpOp::Eq, tid, zero);
    let out = b.label();
    b.bra_if(is0, out);
    b.bar();
    b.bind(out);
    b.ret();
    let k = b.build().unwrap();
    let scalar = trap_under_all_tiers(&k, (1, 1), (4, 1), 0, 0, Limits::default());
    if let Error::VtxTrap { thread, reason, .. } = &scalar {
        assert_eq!(*thread, (1, 0, 0), "must report an actual waiting thread");
        assert!(reason.contains("barrier divergence: 3 threads waiting, 1 exited"), "{reason}");
    }
}

#[test]
fn division_by_zero_trap_identical_across_tiers() {
    // out[tid] = tid_as_int / (tid - 1): thread 1 divides by zero.
    let mut b = KernelBuilder::new("divzero");
    let pout = b.ptr_param();
    let tid = b.tid_x();
    let one = b.consti(1);
    let den = b.isub(tid, one);
    let q = b.idiv(tid, den);
    let qf = b.cvt_i2f(q);
    b.stg(pout, tid, qf);
    b.ret();
    let k = b.build().unwrap();
    let scalar = trap_under_all_tiers(&k, (1, 1), (4, 1), 4, 1, Limits::default());
    if let Error::VtxTrap { thread, reason, .. } = &scalar {
        assert_eq!(*thread, (1, 0, 0));
        assert!(reason.contains("division by zero"), "{reason}");
    }
}

#[test]
fn int_min_division_wraps_identically_across_tiers() {
    // i64::MIN / -1 overflows two's complement: like the other integer
    // ops it must wrap (quotient i64::MIN, remainder 0) instead of
    // panicking, identically on both tiers.
    let mut b = KernelBuilder::new("divmin");
    let pout = b.ptr_param();
    let m = b.consti(i64::MIN);
    let neg1 = b.consti(-1);
    let q = b.idiv(m, neg1);
    let r = b.irem(m, neg1);
    let qf = b.cvt_i2f(q);
    let rf = b.cvt_i2f(r);
    let zero = b.consti(0);
    let one = b.consti(1);
    b.stg(pout, zero, qf);
    b.stg(pout, one, rf);
    b.ret();
    let k = b.build().unwrap();
    let mut outs = Vec::new();
    for (tier, threshold) in TIER_FLAVORS {
        let _g = threshold.map(force_tier_up);
        let mut out = vec![0.0f32; 2];
        execute_with_tier(
            Launch {
                kernel: &k,
                grid: (1, 1),
                block: (1, 1),
                buffers: vec![&mut out],
                scalars: vec![],
                limits: Limits::default(),
            },
            1,
            tier,
        )
        .unwrap();
        outs.push(out);
    }
    for (i, o) in outs.iter().enumerate().skip(1) {
        assert_eq!(&outs[0], o, "tier flavor {i}");
    }
    assert_eq!(outs[0][0], i64::MIN as f32);
    assert_eq!(outs[0][1], 0.0);
}

#[test]
fn fused_rmw_budget_and_oob_traps_interleave_like_scalar() {
    // out[tid] = out[tid] * 3 — LdG;FMul;StG fuses into one RmwG
    // superinstruction on the vector tier. A thread whose step budget
    // expires *mid*-superinstruction, or whose index is OOB right at
    // the budget edge, must report exactly the trap the scalar tier
    // meets first (reason included).
    let scale = {
        let mut b = KernelBuilder::new("scale");
        let p = b.ptr_param();
        let s = b.constf(3.0);
        let tid = b.tid_x();
        let v = b.ldg(p, tid);
        let w = b.fmul(v, s);
        b.stg(p, tid, w);
        b.ret();
        b.build().unwrap()
    };
    // Code: ConstF, Spec, LdG, FMul, StG, Ret (6 steps/thread when it
    // runs to completion).

    // Budget 3, empty buffer: the scalar tier passes the budget check
    // before the LdG (2 < 3) and traps OOB — so must the other tiers,
    // not "step budget exhausted" from a coarse whole-weight charge.
    let scalar =
        trap_under_all_tiers(&scale, (1, 1), (1, 1), 0, 1, Limits { steps_per_thread: 3 });
    if let Error::VtxTrap { reason, .. } = &scalar {
        assert!(reason.contains("global load OOB"), "{reason}");
    }

    // Budget 4, in-bounds buffer: load and multiply retire (steps 3,
    // 4), then the budget expires before the StG on every tier.
    let scalar =
        trap_under_all_tiers(&scale, (1, 1), (1, 1), 1, 1, Limits { steps_per_thread: 4 });
    if let Error::VtxTrap { reason, .. } = &scalar {
        assert!(reason.contains("step budget exhausted (4"), "{reason}");
    }

    // Budget 6: exactly enough — every tier completes.
    let mut ok = |tier: ExecTier, threshold: Option<u64>| {
        let _g = threshold.map(force_tier_up);
        let mut buf = vec![2.0f32];
        execute_with_tier(
            Launch {
                kernel: &scale,
                grid: (1, 1),
                block: (1, 1),
                buffers: vec![&mut buf],
                scalars: vec![],
                limits: Limits { steps_per_thread: 6 },
            },
            1,
            tier,
        )
        .unwrap();
        buf[0]
    };
    for (tier, threshold) in TIER_FLAVORS {
        assert_eq!(ok(tier, threshold), 6.0, "{tier:?} threshold {threshold:?}");
    }
}

#[test]
fn results_bitwise_identical_across_tiers_and_widths() {
    // The real workload kernels under every (tier, width) combination:
    // straight-line + data-divergent (sinogram_all) and shared-memory +
    // barrier (tfunc_column) kernels, bitwise-equal outputs everywhere.
    let size = 16usize;
    let angles = 6usize;
    let img: Vec<f32> = shepp_logan(size).pixels().to_vec();
    let thetas = orientations(angles);

    let sino = hlgpu::emulator::kernels::sinogram_all().unwrap();
    let mut sino_outs: Vec<Vec<f32>> = Vec::new();
    for (tier, threshold) in TIER_FLAVORS {
        for workers in [1usize, 2, 8] {
            let _g = threshold.map(force_tier_up);
            let mut img_b = img.clone();
            let mut ang_b = thetas.clone();
            let mut out = vec![0.0f32; 4 * angles * size];
            execute_with_tier(
                Launch {
                    kernel: &sino,
                    grid: (angles as u32, 1),
                    block: (size as u32, 1),
                    buffers: vec![&mut img_b, &mut ang_b, &mut out],
                    scalars: vec![ScalarArg::I32(size as i32)],
                    limits: Limits::default(),
                },
                workers,
                tier,
            )
            .unwrap();
            sino_outs.push(out);
        }
    }
    for (i, o) in sino_outs.iter().enumerate().skip(1) {
        assert_eq!(&sino_outs[0], o, "sinogram_all combination {i}");
    }

    let (h, w) = (10usize, 6usize);
    let block_h = h.next_power_of_two();
    let red = hlgpu::emulator::kernels::tfunc_column("radon", block_h).unwrap();
    let rimg: Vec<f32> = (0..h * w).map(|i| ((i * 7) % 23) as f32 * 0.5).collect();
    let mut red_outs: Vec<Vec<f32>> = Vec::new();
    for (tier, threshold) in TIER_FLAVORS {
        for workers in [1usize, 8] {
            let _g = threshold.map(force_tier_up);
            let mut img_b = rimg.clone();
            let mut out = vec![0.0f32; w];
            execute_with_tier(
                Launch {
                    kernel: &red,
                    grid: (w as u32, 1),
                    block: (block_h as u32, 1),
                    buffers: vec![&mut img_b, &mut out],
                    scalars: vec![ScalarArg::I32(h as i32), ScalarArg::I32(w as i32)],
                    limits: Limits::default(),
                },
                workers,
                tier,
            )
            .unwrap();
            red_outs.push(out);
        }
    }
    for (i, o) in red_outs.iter().enumerate().skip(1) {
        assert_eq!(&red_outs[0], o, "tfunc_column combination {i}");
    }
}

#[test]
fn vector_tier_reports_fusion_and_lane_occupancy() {
    // Straight-line vadd: the vector tier must retire the same
    // instruction count as the scalar tier, in fewer dispatches, with a
    // nonzero fused share and near-full lanes.
    let k = hlgpu::emulator::kernels::vadd().unwrap();
    let n = 512usize;
    let mut report = |tier: ExecTier| {
        let mut a = vec![1.0f32; n];
        let mut b = vec![2.0f32; n];
        let mut c = vec![0.0f32; n];
        execute_with_tier(
            Launch {
                kernel: &k,
                grid: ((n / 64) as u32, 1),
                block: (64, 1),
                buffers: vec![&mut a, &mut b, &mut c],
                scalars: vec![ScalarArg::I32(n as i32)],
                limits: Limits::default(),
            },
            1,
            tier,
        )
        .unwrap()
    };
    let scalar = report(ExecTier::Scalar);
    let vector = report(ExecTier::Vector);
    assert_eq!(scalar.instrs, vector.instrs, "tiers retire the same instructions");
    assert_eq!(scalar.fused_instrs, 0);
    assert!(vector.fused_instrs > 0, "vadd's index prologue fuses");
    assert!(vector.dispatches < scalar.dispatches, "dispatch amortization");
    assert!(vector.lane_utilization() > 0.9, "straight-line kernel, near-full masks");
}

#[test]
fn compiled_tier_reports_tier_ups_and_high_compiled_share() {
    // The loop-heavy workload kernel under forced compilation: same
    // retired-instruction count as the scalar reference, with almost
    // every instruction executed by compiled block bodies (>0.9 is the
    // steady-state bar), at least one tier-up, and no deopts on the
    // clean path. A mid-run threshold must also tier up: early block
    // entries run vectorized, later ones compiled.
    let size = 16usize;
    let angles = 8usize;
    let img: Vec<f32> = shepp_logan(size).pixels().to_vec();
    let thetas = orientations(angles);
    let k = hlgpu::emulator::kernels::sinogram_all().unwrap();
    let mut report = |tier: ExecTier, threshold: Option<u64>| {
        let _g = threshold.map(force_tier_up);
        let mut img_b = img.clone();
        let mut ang_b = thetas.clone();
        let mut out = vec![0.0f32; 4 * angles * size];
        execute_with_tier(
            Launch {
                kernel: &k,
                grid: (angles as u32, 1),
                block: (size as u32, 1),
                buffers: vec![&mut img_b, &mut ang_b, &mut out],
                scalars: vec![ScalarArg::I32(size as i32)],
                limits: Limits::default(),
            },
            1,
            tier,
        )
        .unwrap()
    };
    let scalar = report(ExecTier::Scalar, None);
    assert_eq!(scalar.compiled_instrs, 0);
    assert_eq!(scalar.compiled_share(), 0.0);

    let forced = report(ExecTier::Compiled, Some(0));
    assert_eq!(forced.instrs, scalar.instrs, "tiers retire the same instructions");
    assert!(forced.tier_ups > 0, "forced compile must promote blocks");
    assert!(forced.compiled_blocks > 0);
    assert_eq!(forced.deopts, 0, "clean run must not deopt");
    assert!(
        forced.compiled_share() > 0.9,
        "compiled share {} too low",
        forced.compiled_share()
    );

    let mid = report(ExecTier::Compiled, Some(4));
    assert_eq!(mid.instrs, scalar.instrs);
    assert!(mid.tier_ups > 0, "hot loop blocks must cross a threshold of 4");
    assert!(
        mid.compiled_instrs > 0 && mid.compiled_instrs < mid.instrs,
        "mid-run tier-up mixes vector and compiled execution"
    );
}

#[test]
fn deopt_restores_vector_tier_state_bitwise() {
    // A kernel that stores to a large buffer, then loads OOB from a
    // small one for high thread ids. Under forced compilation the
    // block body runs compiled until the load's bounds guard fails,
    // deopts, and the vector op path replays from that exact op. The
    // trap must match the vector tier's AND the partially-written
    // output buffer must be bitwise identical to the vector tier's —
    // i.e. the deopt left exactly the state vector execution would
    // have produced (all-or-nothing compiled ops, no partial side
    // effects from the faulting op).
    let mut b = KernelBuilder::new("deopt_state");
    let pout = b.ptr_param();
    let pin = b.ptr_param();
    let tid = b.tid_x();
    let tf = b.cvt_i2f(tid);
    b.stg(pout, tid, tf); // in-bounds for all 8 threads
    let v = b.ldg(pin, tid); // OOB for tid >= 5
    b.stg(pout, tid, v);
    b.ret();
    let k = b.build().unwrap();

    let mut run = |tier: ExecTier, threshold: Option<u64>| -> (Error, Vec<f32>) {
        let _g = threshold.map(force_tier_up);
        let mut out = vec![-1.0f32; 8];
        let mut small = vec![7.0f32; 5];
        let err = execute_with_tier(
            Launch {
                kernel: &k,
                grid: (1, 1),
                block: (8, 1),
                buffers: vec![&mut out, &mut small],
                scalars: vec![],
                limits: Limits::default(),
            },
            1,
            tier,
        )
        .unwrap_err();
        (err, out)
    };
    let (verr, vout) = run(ExecTier::Vector, None);
    let (cerr, cout) = run(ExecTier::Compiled, Some(0));
    assert_same_trap(&verr, &cerr);
    if let Error::VtxTrap { thread, reason, .. } = &verr {
        assert_eq!(*thread, (5, 0, 0), "first OOB lane");
        assert!(reason.contains("global load OOB"), "{reason}");
    }
    // The first store retired for every lane (compiled), the faulting
    // load had no side effects, and the replayed vector path let the
    // surviving lanes 0..5 run to quiescence (second store): state
    // must equal the vector tier's bit for bit.
    assert_eq!(vout, cout, "deopt must restore vector-tier state bitwise");
    assert_eq!(vout, vec![7.0, 7.0, 7.0, 7.0, 7.0, 5.0, 6.0, 7.0]);
}

#[test]
fn results_bitwise_identical_across_schedules_sinogram() {
    // The real workload kernel, multi-block grid, both schedules:
    // bitwise-equal outputs (block writes are disjoint).
    let k = hlgpu::emulator::kernels::sinogram_all().unwrap();
    let size = 20usize;
    let angles = 12usize;
    let img: Vec<f32> = shepp_logan(size).pixels().to_vec();
    let thetas = orientations(angles);
    let mut outputs: Vec<Vec<f32>> = Vec::new();
    for workers in [1usize, 2, 8] {
        let mut img_b = img.clone();
        let mut ang_b = thetas.clone();
        let mut out = vec![0.0f32; 4 * angles * size];
        execute_with(
            Launch {
                kernel: &k,
                grid: (angles as u32, 1),
                block: (size as u32, 1),
                buffers: vec![&mut img_b, &mut ang_b, &mut out],
                scalars: vec![ScalarArg::I32(size as i32)],
                limits: Limits::default(),
            },
            workers,
        )
        .unwrap();
        outputs.push(out);
    }
    assert_eq!(outputs[0], outputs[1], "1 vs 2 workers");
    assert_eq!(outputs[0], outputs[2], "1 vs 8 workers");
}

// ---------------------------------------------------------------- part 4 --
// The device-resident P/F reduction stage vs the host reference.

/// `circus_all → features_all` on device vs `reduce_sinogram` on the
/// host, over independently-generated sinograms: every T-functional,
/// multiple sizes, multiple seeds. The device stage reduces pairwise
/// (tree) where the host reduces sequentially, so comparison is
/// tolerance-based, not bitwise.
#[test]
fn device_reduce_matches_reduce_sinogram_across_t_sizes_and_seeds() {
    use hlgpu::driver::{KernelArg, LaunchConfig, ModuleSource};
    use hlgpu::tracetransform::functionals::reduce_sinogram;
    use hlgpu::tracetransform::{rotate, T_SET};

    let ctx = hlgpu::driver::Context::create(&hlgpu::driver::emulator_device().unwrap()).unwrap();
    for &size in &[8usize, 12, 17] {
        let angles = size / 2 + 1;
        let thetas = orientations(angles);
        for seed in 0..3u64 {
            let img = random_phantom(size, 700 + seed);
            // stack every T-functional's sinogram: [|T|, a, s]
            let mut stacked: Vec<f32> = Vec::with_capacity(T_SET.len() * angles * size);
            let mut want: Vec<f32> = Vec::new();
            for t in T_SET {
                let sino = rotate::sinogram(&img, &thetas, t);
                want.extend(reduce_sinogram(&sino, angles, size));
                stacked.extend(sino);
            }

            let nt = T_SET.len();
            let np = 3usize;
            let bh_s = size.next_power_of_two();
            let bh_a = angles.next_power_of_two();
            let g_sino = ctx.alloc(stacked.len() * 4).unwrap();
            let g_cir = ctx.alloc(nt * np * angles * 4).unwrap();
            let g_feat = ctx.alloc(FEATURE_COUNT * 4).unwrap();
            let bytes: Vec<u8> = stacked.iter().flat_map(|v| v.to_le_bytes()).collect();
            ctx.upload(g_sino, &bytes).unwrap();

            let ck = hlgpu::emulator::kernels::circus_all(bh_s).unwrap();
            let cname = ck.name.clone();
            let cmod = ctx
                .load_module(&ModuleSource::Vtx { kernels: vec![ck] })
                .unwrap();
            cmod.function(&cname)
                .unwrap()
                .launch(
                    &LaunchConfig::new((angles as u32, nt as u32), bh_s as u32),
                    &[
                        KernelArg::Ptr(g_sino),
                        KernelArg::Ptr(g_cir),
                        KernelArg::I32(size as i32),
                    ],
                    ctx.memory().unwrap(),
                )
                .unwrap();
            let fk = hlgpu::emulator::kernels::features_all(bh_a).unwrap();
            let fname = fk.name.clone();
            let fmod = ctx
                .load_module(&ModuleSource::Vtx { kernels: vec![fk] })
                .unwrap();
            fmod.function(&fname)
                .unwrap()
                .launch(
                    &LaunchConfig::new((np as u32, nt as u32), bh_a as u32),
                    &[
                        KernelArg::Ptr(g_cir),
                        KernelArg::Ptr(g_feat),
                        KernelArg::I32(angles as i32),
                    ],
                    ctx.memory().unwrap(),
                )
                .unwrap();

            let mut out = vec![0u8; FEATURE_COUNT * 4];
            ctx.download(g_feat, &mut out).unwrap();
            let got: Vec<f32> = out
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            assert_close(
                &format!("device-reduce s={size} a={angles} seed={seed}"),
                &got,
                &want,
                1e-4,
            );
            ctx.free(g_sino).unwrap();
            ctx.free(g_cir).unwrap();
            ctx.free(g_feat).unwrap();
        }
    }
}

// ---------------------------------------------------------------- part 5 --
//
// Multi-device equivalence: sharding a `features_batch` across a
// `DeviceSet` must be *bitwise* identical to running the same batch on a
// single device.  Each image's feature block is computed independently
// (the batched kernels grid over `(angle, image)` and never mix images),
// chunks are placed deterministically, and reassembly is by absolute
// index — so the shard seams cannot perturb a single bit.

/// Sharded execution across 2- and 4-member device sets reproduces the
/// single-device result exactly, cold and warm.
#[test]
fn sharded_batch_matches_single_device_bitwise() {
    use hlgpu::driver::DeviceSet;
    use hlgpu::tracetransform::ShardMode;

    let thetas = orientations(9);
    for (size, n, seed0) in [(12usize, 5usize, 500u64), (16, 11, 600)] {
        let imgs: Vec<_> = (0..n)
            .map(|i| random_phantom(size, seed0 + i as u64))
            .collect();

        let mut single = GpuAuto::on_device(DeviceChoice::Emulator)
            .unwrap()
            .with_shard(Some(ShardMode::Off));
        let want = single.features_batch(&imgs, &thetas).unwrap();

        for k in [2usize, 4] {
            let mut multi = GpuAuto::on_set(DeviceSet::emulator(k).unwrap())
                .unwrap()
                .with_shard(Some(ShardMode::Auto));
            let cold = multi.features_batch(&imgs, &thetas).unwrap();
            assert_eq!(cold, want, "cold {k}-device shard s={size} n={n}");
            // Warm pass: every lane reuses its cached pipes + replicas.
            let warm = multi.features_batch(&imgs, &thetas).unwrap();
            assert_eq!(warm, want, "warm {k}-device shard s={size} n={n}");
        }
    }
}

/// A set with asymmetric per-member memory capacities (the
/// `HLGPU_DEV_MEM` shape, built here via `Device::emulator_at`) shards
/// correctly as long as every member can hold its chunk working set.
#[test]
fn mixed_capacity_set_matches_single_device_bitwise() {
    use hlgpu::driver::{device_count, Device, DeviceSet};
    use hlgpu::tracetransform::ShardMode;

    let thetas = orientations(7);
    let imgs: Vec<_> = (0..6).map(|i| random_phantom(12, 700 + i)).collect();

    let mut single = GpuAuto::on_device(DeviceChoice::Emulator)
        .unwrap()
        .with_shard(Some(ShardMode::Off));
    let want = single.features_batch(&imgs, &thetas).unwrap();

    // One roomy member, one deliberately small (16 MiB) member: plenty
    // for a few 12x12 chunks, nothing like the default capacity.
    let base = device_count();
    let set = DeviceSet::new(&[
        Device::emulator_at(base, None),
        Device::emulator_at(base + 1, Some(16 << 20)),
    ])
    .unwrap();
    let mut multi = GpuAuto::on_set(set)
        .unwrap()
        .with_shard(Some(ShardMode::Auto));
    let got = multi.features_batch(&imgs, &thetas).unwrap();
    assert_eq!(got, want, "asymmetric-capacity shard diverged");
}

/// Degenerate shards: a single-image batch cannot be split (the sharded
/// path requires at least two images) and an empty batch short-circuits;
/// both must agree with the single-device path.
#[test]
fn degenerate_batches_shard_identically() {
    use hlgpu::driver::DeviceSet;
    use hlgpu::tracetransform::ShardMode;

    let thetas = orientations(6);
    let img = vec![random_phantom(10, 42)];

    let mut single = GpuAuto::on_device(DeviceChoice::Emulator)
        .unwrap()
        .with_shard(Some(ShardMode::Off));
    let want = single.features_batch(&img, &thetas).unwrap();

    let mut multi = GpuAuto::on_set(DeviceSet::emulator(3).unwrap())
        .unwrap()
        .with_shard(Some(ShardMode::Auto));
    assert_eq!(multi.features_batch(&img, &thetas).unwrap(), want);
    assert!(multi.features_batch(&[], &thetas).unwrap().is_empty());
}
