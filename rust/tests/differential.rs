//! Differential test harness for the parallel block scheduler.
//!
//! Two claims are proven here:
//!
//! 1. **Numerical equivalence across implementations**: the emulator-path
//!    trace-transform implementations (`gpu-manual`, `gpu-dynamic`,
//!    `gpu-auto` — all ultimately executing VTX kernels through the
//!    parallel scheduler) agree element-wise with the native CPU
//!    reference across multiple image sizes and PRNG seeds.
//! 2. **Schedule equivalence**: the parallel schedule is observationally
//!    identical to the sequential one — bitwise-equal kernel results for
//!    every pool width, and *identical trap coordinates and reasons* for
//!    every trap class (OOB access, barrier divergence, step-budget
//!    exhaustion).

use hlgpu::emulator::{
    execute_with, KernelBuilder, Launch, Limits, ScalarArg,
};
use hlgpu::error::Error;
use hlgpu::tracetransform::{
    orientations, random_phantom, shepp_logan, CpuNative, DeviceChoice, GpuAuto, GpuDynamic,
    GpuManual, TraceImpl, FEATURE_COUNT,
};

fn assert_close(name: &str, got: &[f32], want: &[f32], rel: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= rel * w.abs().max(1.0),
            "{name}: feature {i}: {g} vs {w}"
        );
    }
}

// ---------------------------------------------------------------- part 1 --

#[test]
fn emulator_impls_match_cpu_native_across_sizes_and_seeds() {
    let thetas = orientations(8);
    for &size in &[12usize, 16, 24] {
        for seed in 0..3u64 {
            let img = random_phantom(size, 100 + seed);
            let want = CpuNative::new().features(&img, &thetas).unwrap();
            assert_eq!(want.len(), FEATURE_COUNT);

            let manual = GpuManual::on_device(DeviceChoice::Emulator)
                .unwrap()
                .features(&img, &thetas)
                .unwrap();
            assert_close(&format!("gpu-manual s={size} seed={seed}"), &manual, &want, 2e-3);

            let dynamic = GpuDynamic::on_device(DeviceChoice::Emulator)
                .unwrap()
                .features(&img, &thetas)
                .unwrap();
            assert_close(&format!("gpu-dynamic s={size} seed={seed}"), &dynamic, &want, 2e-3);

            let auto = GpuAuto::on_device(DeviceChoice::Emulator)
                .unwrap()
                .features(&img, &thetas)
                .unwrap();
            assert_close(&format!("gpu-auto s={size} seed={seed}"), &auto, &want, 2e-3);
        }
    }
}

#[test]
fn shepp_logan_differential_at_multiple_sizes() {
    let thetas = orientations(10);
    for &size in &[16usize, 20] {
        let img = shepp_logan(size);
        let want = CpuNative::new().features(&img, &thetas).unwrap();
        let auto = GpuAuto::on_device(DeviceChoice::Emulator)
            .unwrap()
            .features(&img, &thetas)
            .unwrap();
        assert_close(&format!("gpu-auto shepp-logan s={size}"), &auto, &want, 2e-3);
    }
}

// ---------------------------------------------------------------- part 2 --

/// vadd without a tail guard: OOB as soon as a thread's global index
/// reaches past the (undersized) buffers.
fn unguarded_vadd() -> hlgpu::emulator::Kernel {
    let mut b = KernelBuilder::new("vadd_unguarded");
    let pa = b.ptr_param();
    let pb = b.ptr_param();
    let pc = b.ptr_param();
    let tid = b.tid_x();
    let bid = b.ctaid_x();
    let bdim = b.ntid_x();
    let base = b.imul(bid, bdim);
    let gid = b.iadd(base, tid);
    let x = b.ldg(pa, gid);
    let y = b.ldg(pb, gid);
    let s = b.fadd(x, y);
    b.stg(pc, gid, s);
    b.ret();
    b.build().unwrap()
}

/// Run the same launch under both schedules and return both errors.
fn trap_under_both_schedules(
    k: &hlgpu::emulator::Kernel,
    grid: (u32, u32),
    block: (u32, u32),
    buf_len: usize,
    nbufs: usize,
    limits: Limits,
) -> (Error, Error) {
    let mut run = |workers: usize| -> Error {
        let mut bufs: Vec<Vec<f32>> = (0..nbufs).map(|_| vec![1.0f32; buf_len]).collect();
        let views: Vec<&mut [f32]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
        execute_with(
            Launch {
                kernel: k,
                grid,
                block,
                buffers: views,
                scalars: vec![],
                limits,
            },
            workers,
        )
        .unwrap_err()
    };
    (run(1), run(8))
}

fn assert_same_trap(seq: &Error, par: &Error) {
    match (seq, par) {
        (
            Error::VtxTrap { kernel: k1, block: b1, thread: t1, reason: r1 },
            Error::VtxTrap { kernel: k2, block: b2, thread: t2, reason: r2 },
        ) => {
            assert_eq!(k1, k2, "kernel name");
            assert_eq!(b1, b2, "block coordinates");
            assert_eq!(t1, t2, "thread coordinates");
            assert_eq!(r1, r2, "trap reason");
        }
        other => panic!("expected two VtxTrap errors, got {other:?}"),
    }
}

#[test]
fn oob_trap_identical_under_parallel_schedule() {
    let k = unguarded_vadd();
    // 8 blocks x 16 threads = 128 global ids, buffers of 40 elements:
    // the first OOB thread the sequential schedule meets is block 2,
    // thread 8 (gid 40). The parallel schedule must report the same one.
    let (seq, par) = trap_under_both_schedules(&k, (8, 1), (16, 1), 40, 3, Limits::default());
    assert_same_trap(&seq, &par);
    if let Error::VtxTrap { block, thread, reason, .. } = &seq {
        assert_eq!(*block, (2, 0, 0));
        assert_eq!(*thread, (8, 0, 0));
        assert!(reason.contains("OOB"), "{reason}");
    }
}

#[test]
fn barrier_divergence_trap_identical_under_parallel_schedule() {
    // threads with tid==0 exit before the barrier in EVERY block; the
    // reported divergence must come from block (0,0) under both
    // schedules (lowest block index wins).
    let mut b = KernelBuilder::new("diverge_all_blocks");
    let tid = b.tid_x();
    let zero = b.consti(0);
    let is0 = b.cmpi(hlgpu::emulator::isa::CmpOp::Eq, tid, zero);
    let out = b.label();
    b.bra_if(is0, out);
    b.bar();
    b.bind(out);
    b.ret();
    let k = b.build().unwrap();
    let (seq, par) = trap_under_both_schedules(&k, (6, 1), (4, 1), 0, 0, Limits::default());
    assert_same_trap(&seq, &par);
    if let Error::VtxTrap { block, reason, .. } = &seq {
        assert_eq!(*block, (0, 0, 0));
        assert!(reason.contains("barrier divergence"), "{reason}");
    }
}

#[test]
fn step_budget_trap_identical_under_parallel_schedule() {
    // every thread of every block spins; the reported exhaustion must be
    // block (0,0), thread (0,0) under both schedules.
    let mut b = KernelBuilder::new("spin_grid");
    let top = b.label();
    b.bind(top);
    b.bra(top);
    let k = b.build().unwrap();
    let (seq, par) = trap_under_both_schedules(
        &k,
        (4, 1),
        (2, 1),
        0,
        0,
        Limits { steps_per_thread: 500 },
    );
    assert_same_trap(&seq, &par);
    if let Error::VtxTrap { block, thread, reason, .. } = &seq {
        assert_eq!(*block, (0, 0, 0));
        assert_eq!(*thread, (0, 0, 0));
        assert!(reason.contains("step budget"), "{reason}");
    }
}

#[test]
fn results_bitwise_identical_across_schedules_sinogram() {
    // The real workload kernel, multi-block grid, both schedules:
    // bitwise-equal outputs (block writes are disjoint).
    let k = hlgpu::emulator::kernels::sinogram_all().unwrap();
    let size = 20usize;
    let angles = 12usize;
    let img: Vec<f32> = shepp_logan(size).pixels().to_vec();
    let thetas = orientations(angles);
    let mut outputs: Vec<Vec<f32>> = Vec::new();
    for workers in [1usize, 2, 8] {
        let mut img_b = img.clone();
        let mut ang_b = thetas.clone();
        let mut out = vec![0.0f32; 4 * angles * size];
        execute_with(
            Launch {
                kernel: &k,
                grid: (angles as u32, 1),
                block: (size as u32, 1),
                buffers: vec![&mut img_b, &mut ang_b, &mut out],
                scalars: vec![ScalarArg::I32(size as i32)],
                limits: Limits::default(),
            },
            workers,
        )
        .unwrap();
        outputs.push(out);
    }
    assert_eq!(outputs[0], outputs[1], "1 vs 2 workers");
    assert_eq!(outputs[0], outputs[2], "1 vs 8 workers");
}
