//! Property-based tests (in-repo harness — no proptest crate offline):
//! randomized inputs from the deterministic `Prng`, with the failing seed
//! printed on assertion failure so cases replay exactly.
//!
//! Invariants covered:
//!  * memory pool: alloc/free/copy sequences never corrupt unrelated
//!    buffers; stats stay consistent; OOM respects capacity; the cached
//!    and uncached allocation policies are observationally identical
//!    through `DeviceArray` round-trips;
//!  * VTX interpreter: generated vadd/affine programs match scalar rust
//!    evaluation for arbitrary sizes and launch geometries;
//!  * coordinator: for random shapes, the specialization cache key is
//!    injective on (shape, mode) and launches through the automation layer
//!    equal direct emulator execution;
//!  * trace functionals: linearity of the linear T/P functionals,
//!    rotation invariants of the sinogram;
//!  * stats: log-normal fit bounds (mean between min and max, etc.);
//!  * JSON parser: round-trips machine-generated manifests of random
//!    shape.

use hlgpu::coordinator::{arg, Launcher, VtxSpec};
use hlgpu::driver::{KernelArg, LaunchConfig, MemoryPool};
use hlgpu::emulator::kernels;
use hlgpu::tensor::Tensor;
use hlgpu::util::{Json, Prng};
use std::sync::{Mutex, MutexGuard};

const CASES: usize = 40;

/// The tier-up override is process-global, so every compiled-tier run
/// in this binary scopes it through this lock (restored on drop, even
/// across a failing assertion).
static TIER_UP_LOCK: Mutex<()> = Mutex::new(());

struct TierUpGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for TierUpGuard {
    fn drop(&mut self) {
        hlgpu::emulator::set_default_tier_up(None);
    }
}

fn force_tier_up(threshold: u64) -> TierUpGuard {
    let g = TIER_UP_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    hlgpu::emulator::set_default_tier_up(Some(threshold));
    TierUpGuard(g)
}

/// Tier flavors for the cross-tier property tests: scalar reference,
/// vector, compiled with every block force-compiled on first entry
/// (`HLGPU_TIER_UP=0` semantics), and compiled tiering up mid-run.
const TIER_FLAVORS: [(hlgpu::emulator::ExecTier, Option<u64>); 4] = [
    (hlgpu::emulator::ExecTier::Scalar, None),
    (hlgpu::emulator::ExecTier::Vector, None),
    (hlgpu::emulator::ExecTier::Compiled, Some(0)),
    (hlgpu::emulator::ExecTier::Compiled, Some(2)),
];

// --------------------------------------------------------------- memory --

#[test]
fn prop_memory_pool_isolation_under_random_ops() {
    for seed in 0..CASES as u64 {
        let mut rng = Prng::new(seed);
        let pool = MemoryPool::new(1 << 20);
        // allocate a set of buffers with known sentinel patterns
        let n = rng.usize_in(2, 12);
        let mut live: Vec<(hlgpu::driver::DevicePtr, u8, usize)> = Vec::new();
        for i in 0..n {
            let len = rng.usize_in(1, 4096);
            let ptr = pool.alloc(len).unwrap();
            let tag = (i + 1) as u8;
            pool.copy_h2d(ptr, &vec![tag; len]).unwrap();
            live.push((ptr, tag, len));
        }
        // random interleaving of frees, writes and reads
        for _ in 0..30 {
            match rng.usize_in(0, 2) {
                0 if !live.is_empty() => {
                    let idx = rng.usize_in(0, live.len() - 1);
                    let (ptr, _, _) = live.remove(idx);
                    pool.free(ptr).unwrap();
                }
                1 if !live.is_empty() => {
                    let idx = rng.usize_in(0, live.len() - 1);
                    let (ptr, tag, len) = live[idx];
                    // overwrite with the same tag (content must stay stable)
                    pool.copy_h2d(ptr, &vec![tag; len]).unwrap();
                }
                _ => {}
            }
            // every live buffer still holds its own tag — no cross-talk
            for &(ptr, tag, len) in &live {
                let mut out = vec![0u8; len];
                pool.copy_d2h(ptr, &mut out).unwrap();
                assert!(
                    out.iter().all(|&b| b == tag),
                    "seed {seed}: buffer {ptr:?} corrupted"
                );
            }
        }
        let st = pool.stats();
        assert_eq!(st.alloc_count as usize, n, "seed {seed}");
        assert_eq!(pool.live_buffers(), live.len(), "seed {seed}");
    }
}

#[test]
fn prop_memory_capacity_never_exceeded() {
    for seed in 0..CASES as u64 {
        let mut rng = Prng::new(1000 + seed);
        let cap = rng.usize_in(1024, 1 << 16);
        let pool = MemoryPool::new(cap);
        let mut live = Vec::new();
        for _ in 0..64 {
            let len = rng.usize_in(1, cap / 2);
            match pool.alloc(len) {
                Ok(p) => live.push(p),
                Err(e) => {
                    assert_eq!(e.status(), "ERROR_OUT_OF_MEMORY", "seed {seed}");
                }
            }
            if rng.bool() {
                if let Some(p) = live.pop() {
                    pool.free(p).unwrap();
                }
            }
            assert!(pool.stats().current_bytes <= cap, "seed {seed}");
            assert!(pool.stats().peak_bytes <= cap, "seed {seed}");
        }
    }
}

#[test]
fn prop_cached_and_uncached_policies_observationally_identical() {
    // Same random alloc/upload/download/free schedule against a cached
    // and an uncached pool: every download must return the uploaded
    // data, identically under both policies, and the live-byte gauges
    // must track each other (only the reuse counters may differ).
    use hlgpu::coordinator::DeviceArray;
    use hlgpu::driver::{Context, PoolPolicy};
    for seed in 0..CASES as u64 {
        let mut rng = Prng::new(11_000 + seed);
        let dev = hlgpu::driver::emulator_device().unwrap();
        let cached = Context::create_with_policy(&dev, PoolPolicy::Cached).unwrap();
        let uncached = Context::create_with_policy(&dev, PoolPolicy::Uncached).unwrap();
        let mut live: Vec<(DeviceArray, DeviceArray, Vec<f32>)> = Vec::new();
        for _ in 0..24 {
            match rng.usize_in(0, 2) {
                0 => {
                    let n = rng.usize_in(1, 512);
                    let vals = rng.f32_vec(n, -10.0, 10.0);
                    let t = Tensor::from_f32(&vals, &[n]);
                    let a = DeviceArray::from_tensor(&cached, &t).unwrap();
                    let b = DeviceArray::from_tensor(&uncached, &t).unwrap();
                    live.push((a, b, vals));
                }
                1 if !live.is_empty() => {
                    let idx = rng.usize_in(0, live.len() - 1);
                    let (a, b, _) = live.remove(idx);
                    a.free().unwrap();
                    b.free().unwrap();
                }
                _ => {
                    for (a, b, vals) in &live {
                        let da = a.download().unwrap();
                        let db = b.download().unwrap();
                        assert_eq!(da.as_f32(), vals.as_slice(), "seed {seed}: cached");
                        assert_eq!(da.as_f32(), db.as_f32(), "seed {seed}: policies differ");
                    }
                }
            }
        }
        let sa = cached.mem_stats().unwrap();
        let sb = uncached.mem_stats().unwrap();
        assert_eq!(sa.current_bytes, sb.current_bytes, "seed {seed}");
        assert_eq!(sa.peak_bytes, sb.peak_bytes, "seed {seed}");
        assert_eq!(sa.alloc_count, sb.alloc_count, "seed {seed}");
        assert_eq!(sa.free_count, sb.free_count, "seed {seed}");
        assert_eq!(sb.reuse_count, 0, "seed {seed}: uncached never reuses");
        assert_eq!(sb.cached_bytes, 0, "seed {seed}");
    }
}

// ------------------------------------------------------------- emulator --

#[test]
fn prop_vtx_vadd_matches_scalar_for_any_geometry() {
    let k = kernels::vadd().unwrap();
    for seed in 0..CASES as u64 {
        let mut rng = Prng::new(2000 + seed);
        let n = rng.usize_in(1, 3000);
        let block = *rng.choose(&[1u32, 7, 32, 128, 256]);
        let grid = (n as u32).div_ceil(block);
        let mut a = rng.f32_vec(n, -10.0, 10.0);
        let mut b = rng.f32_vec(n, -10.0, 10.0);
        let want: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        let mut c = vec![0.0f32; n];
        hlgpu::emulator::execute(hlgpu::emulator::Launch {
            kernel: &k,
            grid: (grid, 1),
            block: (block, 1),
            buffers: vec![&mut a, &mut b, &mut c],
            scalars: vec![hlgpu::emulator::ScalarArg::I32(n as i32)],
            limits: hlgpu::emulator::Limits::default(),
        })
        .unwrap();
        assert_eq!(c, want, "seed {seed} n {n} block {block}");
    }
}

#[test]
fn prop_vtx_reduction_matches_for_power_of_two_blocks() {
    for seed in 0..16u64 {
        let mut rng = Prng::new(3000 + seed);
        let h = rng.usize_in(2, 60);
        let w = rng.usize_in(1, 20);
        let block_h = h.next_power_of_two();
        let k = kernels::tfunc_column("radon", block_h).unwrap();
        let mut img = rng.f32_vec(h * w, -5.0, 5.0);
        let mut out = vec![0.0f32; w];
        hlgpu::emulator::execute(hlgpu::emulator::Launch {
            kernel: &k,
            grid: (w as u32, 1),
            block: (block_h as u32, 1),
            buffers: vec![&mut img, &mut out],
            scalars: vec![
                hlgpu::emulator::ScalarArg::I32(h as i32),
                hlgpu::emulator::ScalarArg::I32(w as i32),
            ],
            limits: hlgpu::emulator::Limits::default(),
        })
        .unwrap();
        for col in 0..w {
            let want: f32 = (0..h).map(|r| img[r * w + col]).sum();
            assert!(
                (out[col] - want).abs() < 1e-3,
                "seed {seed} col {col}: {} vs {want}",
                out[col]
            );
        }
    }
}

#[test]
fn prop_scheduler_deterministic_across_pool_sizes() {
    // The parallel block scheduler must be observationally identical to
    // the sequential schedule: bitwise-equal outputs for pool widths 1,
    // 2 and 8 on arbitrary launch geometries.
    let k = kernels::vadd().unwrap();
    for seed in 0..16u64 {
        let mut rng = Prng::new(9000 + seed);
        let n = rng.usize_in(1, 4000);
        let block = *rng.choose(&[1u32, 7, 32, 64]);
        let grid = (n as u32).div_ceil(block);
        let a = rng.f32_vec(n, -10.0, 10.0);
        let b = rng.f32_vec(n, -10.0, 10.0);
        let mut outputs: Vec<Vec<f32>> = Vec::new();
        for workers in [1usize, 2, 8] {
            let mut aa = a.clone();
            let mut bb = b.clone();
            let mut c = vec![0.0f32; n];
            hlgpu::emulator::execute_with(
                hlgpu::emulator::Launch {
                    kernel: &k,
                    grid: (grid, 1),
                    block: (block, 1),
                    buffers: vec![&mut aa, &mut bb, &mut c],
                    scalars: vec![hlgpu::emulator::ScalarArg::I32(n as i32)],
                    limits: hlgpu::emulator::Limits::default(),
                },
                workers,
            )
            .unwrap_or_else(|e| panic!("seed {seed} workers {workers}: {e}"));
            outputs.push(c);
        }
        assert_eq!(outputs[0], outputs[1], "seed {seed}: 1 vs 2 workers");
        assert_eq!(outputs[0], outputs[2], "seed {seed}: 1 vs 8 workers");
    }
}

#[test]
fn prop_scheduler_repeated_runs_identical() {
    // Same seed, same pool width, repeated runs: bitwise-identical
    // results (no scheduling nondeterminism leaks into the data).
    let k = kernels::sinogram_all().unwrap();
    for seed in 0..4u64 {
        let mut rng = Prng::new(9500 + seed);
        let s = rng.usize_in(8, 24);
        let a = rng.usize_in(2, 10);
        let img = rng.f32_vec(s * s, 0.0, 1.0);
        let angles = rng.f32_vec(a, 0.0, 3.14);
        let mut runs: Vec<Vec<f32>> = Vec::new();
        for _ in 0..2 {
            let mut img_b = img.clone();
            let mut ang_b = angles.clone();
            let mut out = vec![0.0f32; 4 * a * s];
            hlgpu::emulator::execute_with(
                hlgpu::emulator::Launch {
                    kernel: &k,
                    grid: (a as u32, 1),
                    block: (s as u32, 1),
                    buffers: vec![&mut img_b, &mut ang_b, &mut out],
                    scalars: vec![hlgpu::emulator::ScalarArg::I32(s as i32)],
                    limits: hlgpu::emulator::Limits::default(),
                },
                8,
            )
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            runs.push(out);
        }
        assert_eq!(runs[0], runs[1], "seed {seed}: repeated runs must agree");
    }
}

#[test]
fn prop_barrier_kernels_deterministic_across_pool_sizes() {
    // Kernels with shared memory + barriers (the tree reduction) under
    // the parallel schedule: same results for every pool width.
    for seed in 0..8u64 {
        let mut rng = Prng::new(9800 + seed);
        let h = rng.usize_in(2, 40);
        let w = rng.usize_in(2, 16);
        let block_h = h.next_power_of_two();
        let k = kernels::tfunc_column("radon", block_h).unwrap();
        let img = rng.f32_vec(h * w, -5.0, 5.0);
        let mut outputs: Vec<Vec<f32>> = Vec::new();
        for workers in [1usize, 8] {
            let mut img_b = img.clone();
            let mut out = vec![0.0f32; w];
            hlgpu::emulator::execute_with(
                hlgpu::emulator::Launch {
                    kernel: &k,
                    grid: (w as u32, 1),
                    block: (block_h as u32, 1),
                    buffers: vec![&mut img_b, &mut out],
                    scalars: vec![
                        hlgpu::emulator::ScalarArg::I32(h as i32),
                        hlgpu::emulator::ScalarArg::I32(w as i32),
                    ],
                    limits: hlgpu::emulator::Limits::default(),
                },
                workers,
            )
            .unwrap_or_else(|e| panic!("seed {seed} workers {workers}: {e}"));
            outputs.push(out);
        }
        assert_eq!(outputs[0], outputs[1], "seed {seed}");
    }
}

/// out[tid] = tid odd ? a[tid] * 2 : a[tid] + 1 — through real branches
/// (not SelF), so lanes diverge and reconverge in the vector tier.
fn divergent_branch_kernel() -> hlgpu::emulator::Kernel {
    use hlgpu::emulator::KernelBuilder;
    let mut b = KernelBuilder::new("divergent");
    let pa = b.ptr_param();
    let pout = b.ptr_param();
    let tid = b.tid_x();
    let bid = b.ctaid_x();
    let bdim = b.ntid_x();
    let base = b.imul(bid, bdim);
    let gid = b.iadd(base, tid);
    let two = b.consti(2);
    let odd = b.irem(gid, two);
    let v = b.ldg(pa, gid);
    let res = b.f();
    let odd_path = b.label();
    let join = b.label();
    b.bra_if(odd, odd_path);
    let one = b.constf(1.0);
    let e = b.fadd(v, one);
    b.movf(res, e);
    b.bra(join);
    b.bind(odd_path);
    let twof = b.constf(2.0);
    let o = b.fmul(v, twof);
    b.movf(res, o);
    b.bind(join);
    b.stg(pout, gid, res);
    b.ret();
    b.build().unwrap()
}

#[test]
fn prop_exec_tiers_observationally_identical() {
    // The warp-vectorized and compiled tiers vs the scalar reference
    // tier, across random launch geometries, pool widths 1/2/8, on
    // straight-line (vadd), divergent-branch and shared-memory (tree
    // reduction) kernels: bitwise-equal outputs everywhere, for both
    // the force-compiled and mid-run tier-up flavors.
    use hlgpu::emulator::execute_with_tier;
    let vadd = kernels::vadd().unwrap();
    let div = divergent_branch_kernel();
    for seed in 0..12u64 {
        let mut rng = Prng::new(12_000 + seed);

        // vadd + divergent kernels share a geometry
        let n = rng.usize_in(1, 2000);
        let block = *rng.choose(&[1u32, 7, 32, 64]);
        let grid = (n as u32).div_ceil(block);
        let a = rng.f32_vec(n, -10.0, 10.0);
        let b = rng.f32_vec(n, -10.0, 10.0);
        let mut vadd_outs: Vec<Vec<f32>> = Vec::new();
        let mut div_outs: Vec<Vec<f32>> = Vec::new();
        for (tier, threshold) in TIER_FLAVORS {
            for workers in [1usize, 2, 8] {
                let _g = threshold.map(force_tier_up);
                let mut aa = a.clone();
                let mut bb = b.clone();
                let mut c = vec![0.0f32; n];
                execute_with_tier(
                    hlgpu::emulator::Launch {
                        kernel: &vadd,
                        grid: (grid, 1),
                        block: (block, 1),
                        buffers: vec![&mut aa, &mut bb, &mut c],
                        scalars: vec![hlgpu::emulator::ScalarArg::I32(n as i32)],
                        limits: hlgpu::emulator::Limits::default(),
                    },
                    workers,
                    tier,
                )
                .unwrap_or_else(|e| panic!("vadd seed {seed} {tier:?} w{workers}: {e}"));
                vadd_outs.push(c);

                // the divergent kernel has no tail guard: pad to the grid
                let padded = (grid * block) as usize;
                let mut ap = a.clone();
                ap.resize(padded, 0.0);
                let mut out = vec![0.0f32; padded];
                execute_with_tier(
                    hlgpu::emulator::Launch {
                        kernel: &div,
                        grid: (grid, 1),
                        block: (block, 1),
                        buffers: vec![&mut ap, &mut out],
                        scalars: vec![],
                        limits: hlgpu::emulator::Limits::default(),
                    },
                    workers,
                    tier,
                )
                .unwrap_or_else(|e| panic!("div seed {seed} {tier:?} w{workers}: {e}"));
                div_outs.push(out);
            }
        }
        for (i, o) in vadd_outs.iter().enumerate().skip(1) {
            assert_eq!(&vadd_outs[0], o, "vadd seed {seed} combination {i}");
        }
        for (i, o) in div_outs.iter().enumerate().skip(1) {
            assert_eq!(&div_outs[0], o, "divergent seed {seed} combination {i}");
        }
        // spot-check the divergent kernel against scalar rust
        for (i, got) in div_outs[0].iter().enumerate().take(n) {
            let x = if i < a.len() { a[i] } else { 0.0 };
            let want = if i % 2 == 1 { x * 2.0 } else { x + 1.0 };
            assert_eq!(*got, want, "divergent seed {seed} elem {i}");
        }

        // shared-memory tree reduction across tiers
        let h = rng.usize_in(2, 40);
        let w = rng.usize_in(2, 12);
        let block_h = h.next_power_of_two();
        let red = kernels::tfunc_column("radon", block_h).unwrap();
        let img = rng.f32_vec(h * w, -5.0, 5.0);
        let mut red_outs: Vec<Vec<f32>> = Vec::new();
        for (tier, threshold) in TIER_FLAVORS {
            for workers in [1usize, 8] {
                let _g = threshold.map(force_tier_up);
                let mut img_b = img.clone();
                let mut out = vec![0.0f32; w];
                execute_with_tier(
                    hlgpu::emulator::Launch {
                        kernel: &red,
                        grid: (w as u32, 1),
                        block: (block_h as u32, 1),
                        buffers: vec![&mut img_b, &mut out],
                        scalars: vec![
                            hlgpu::emulator::ScalarArg::I32(h as i32),
                            hlgpu::emulator::ScalarArg::I32(w as i32),
                        ],
                        limits: hlgpu::emulator::Limits::default(),
                    },
                    workers,
                    tier,
                )
                .unwrap_or_else(|e| panic!("reduce seed {seed} {tier:?} w{workers}: {e}"));
                red_outs.push(out);
            }
        }
        for (i, o) in red_outs.iter().enumerate().skip(1) {
            assert_eq!(&red_outs[0], o, "reduction seed {seed} combination {i}");
        }
    }
}

#[test]
fn prop_trap_parity_across_tiers_on_random_undersized_buffers() {
    // Unguarded vadd with randomly undersized buffers: every tier
    // (including the compiled tier, whose bounds guards deopt onto the
    // vector op path) must report the same trap coordinates and reason
    // as the scalar reference — or all succeed.
    use hlgpu::emulator::{execute_with_tier, ExecTier, KernelBuilder};
    let k = {
        let mut b = KernelBuilder::new("vadd_unguarded_prop");
        let pa = b.ptr_param();
        let pb = b.ptr_param();
        let pc = b.ptr_param();
        let tid = b.tid_x();
        let bid = b.ctaid_x();
        let bdim = b.ntid_x();
        let base = b.imul(bid, bdim);
        let gid = b.iadd(base, tid);
        let x = b.ldg(pa, gid);
        let y = b.ldg(pb, gid);
        let s = b.fadd(x, y);
        b.stg(pc, gid, s);
        b.ret();
        b.build().unwrap()
    };
    for seed in 0..24u64 {
        let mut rng = Prng::new(13_000 + seed);
        let grid = rng.usize_in(1, 8) as u32;
        let block = rng.usize_in(1, 32) as u32;
        let total = (grid * block) as usize;
        let buf_len = rng.usize_in(0, total + 4);
        let mut run = |tier: ExecTier, threshold: Option<u64>| {
            let _g = threshold.map(force_tier_up);
            let mut a = vec![1.0f32; buf_len];
            let mut b = vec![1.0f32; buf_len];
            let mut c = vec![0.0f32; buf_len];
            execute_with_tier(
                hlgpu::emulator::Launch {
                    kernel: &k,
                    grid: (grid, 1),
                    block: (block, 1),
                    buffers: vec![&mut a, &mut b, &mut c],
                    scalars: vec![],
                    limits: hlgpu::emulator::Limits::default(),
                },
                1,
                tier,
            )
        };
        let scalar = run(ExecTier::Scalar, None);
        for (tier, threshold) in TIER_FLAVORS.into_iter().skip(1) {
            match (&scalar, run(tier, threshold)) {
                (Ok(_), Ok(_)) => assert!(buf_len >= total, "seed {seed}: both passed"),
                (Err(se), Err(te)) => {
                    assert_eq!(se.to_string(), te.to_string(), "seed {seed} {tier:?}");
                }
                (s, t) => panic!("seed {seed} {tier:?}: tier disagreement: {s:?} vs {t:?}"),
            }
        }
    }
}

// ---------------------------------------------------------- coordinator --

#[test]
fn prop_automation_equals_direct_emulator_execution() {
    for seed in 0..20u64 {
        let mut rng = Prng::new(4000 + seed);
        let n = rng.usize_in(1, 2000);
        let mut launcher = Launcher::emulator().unwrap();
        launcher.registry_mut().register_vtx("vadd", |specs| {
            let n = specs[0].numel();
            Ok(VtxSpec {
                kernel: kernels::vadd()?,
                scalars: vec![KernelArg::I32(n as i32)],
                config: LaunchConfig::new((n as u32).div_ceil(256), 256u32),
            })
        });
        let a = Tensor::from_f32(&rng.f32_vec(n, -1.0, 1.0), &[n]);
        let b = Tensor::from_f32(&rng.f32_vec(n, -1.0, 1.0), &[n]);
        let mut c = Tensor::zeros_f32(&[n]);
        launcher
            .launch(
                "vadd",
                LaunchConfig::new(1u32, 1u32),
                &mut [arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)],
            )
            .unwrap();
        for i in 0..n {
            let want = a.as_f32()[i] + b.as_f32()[i];
            assert!((c.as_f32()[i] - want).abs() < 1e-6, "seed {seed} i {i}");
        }
    }
}

#[test]
fn prop_cache_keys_injective_on_shape_and_mode() {
    use hlgpu::coordinator::{call_signature, SpecializationCache};
    use std::collections::HashSet;
    let mut rng = Prng::new(5000);
    let mut seen = HashSet::new();
    let mut shapes = Vec::new();
    for _ in 0..60 {
        let rank = rng.usize_in(1, 3);
        let shape: Vec<usize> = (0..rank).map(|_| rng.usize_in(1, 9)).collect();
        shapes.push(shape);
    }
    shapes.sort();
    shapes.dedup();
    for shape in &shapes {
        let t = Tensor::zeros_f32(shape);
        let mut o = Tensor::zeros_f32(shape);
        let sig_in = call_signature(&[arg::cu_in(&t)]);
        let sig_out = call_signature(&[arg::cu_out(&mut o)]);
        assert_ne!(sig_in, sig_out, "mode must be part of the key");
        let k1 = SpecializationCache::<u8>::key("k", &sig_in);
        let k2 = SpecializationCache::<u8>::key("k", &sig_out);
        assert!(seen.insert(k1), "duplicate key for {shape:?} (in)");
        assert!(seen.insert(k2), "duplicate key for {shape:?} (out)");
    }
}

// ------------------------------------------------------------ functionals --

#[test]
fn prop_linear_tfunctionals_are_linear() {
    use hlgpu::tracetransform::TFunctional;
    for seed in 0..CASES as u64 {
        let mut rng = Prng::new(6000 + seed);
        let n = rng.usize_in(2, 64);
        let a = rng.f32_vec(n, -3.0, 3.0);
        let b = rng.f32_vec(n, -3.0, 3.0);
        let sum: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
        for t in [TFunctional::Radon, TFunctional::T1, TFunctional::T2] {
            let fa = t.apply_strided(&a, n, 1);
            let fb = t.apply_strided(&b, n, 1);
            let fs = t.apply_strided(&sum, n, 1);
            let scale = fa.abs().max(fb.abs()).max(1.0);
            assert!(
                (fs - (fa + fb)).abs() < 1e-3 * scale,
                "seed {seed} {t:?}: {fs} vs {}",
                fa + fb
            );
        }
    }
}

#[test]
fn prop_sinogram_rotation_shift() {
    // rotating the *angle set* by delta equals rotating the image by
    // -delta (approximately, up to interpolation error) for the radon
    // functional on smooth content
    use hlgpu::tracetransform::{rotate, TFunctional};
    for seed in 0..6u64 {
        let img = hlgpu::tracetransform::random_phantom(48, seed);
        let delta = 0.35f32;
        let base = rotate::sinogram(&img, &[0.8 + delta], TFunctional::Radon);
        let rotated_img = rotate::rotate(&img, delta);
        let shifted = rotate::sinogram(&rotated_img, &[0.8], TFunctional::Radon);
        // compare interior (edges clip mass)
        let s = img.size();
        let mut diff = 0.0f32;
        let mut norm = 0.0f32;
        for c in s / 4..3 * s / 4 {
            diff += (base[c] - shifted[c]).abs();
            norm += base[c].abs().max(1e-3);
        }
        assert!(diff / norm < 0.08, "seed {seed}: relative diff {}", diff / norm);
    }
}

// ----------------------------------------------------------------- stats --

#[test]
fn prop_lognormal_mean_within_sample_range() {
    for seed in 0..CASES as u64 {
        let mut rng = Prng::new(7000 + seed);
        let n = rng.usize_in(2, 200);
        let samples: Vec<f64> = (0..n).map(|_| 0.5 + rng.next_f64() * 10.0).collect();
        let s = hlgpu::stats::lognormal_fit(&samples);
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = samples.iter().cloned().fold(0.0f64, f64::max);
        // log-normal mean >= geometric mean; stays within [min, max*e^sigma]
        assert!(s.mean >= min * 0.999, "seed {seed}");
        assert!(s.mean <= max * (s.sigma * s.sigma / 2.0).exp() + 1e-9, "seed {seed}");
        assert!(s.rel_uncertainty >= 0.0);
    }
}

// ------------------------------------------------------------------ JSON --

#[test]
fn prop_json_parses_generated_manifests() {
    for seed in 0..CASES as u64 {
        let mut rng = Prng::new(8000 + seed);
        let n = rng.usize_in(1, 10);
        let mut doc = String::from("{\"version\": 1, \"artifacts\": [");
        for i in 0..n {
            if i > 0 {
                doc.push(',');
            }
            let rank = rng.usize_in(1, 4);
            let dims: Vec<String> =
                (0..rank).map(|_| rng.usize_in(1, 512).to_string()).collect();
            doc.push_str(&format!(
                "{{\"name\": \"k{i}\", \"kernel\": \"k\", \"path\": \"k{i}.hlo.txt\", \
                 \"inputs\": [{{\"dtype\": \"f32\", \"shape\": [{dims}]}}], \
                 \"outputs\": [{{\"dtype\": \"f32\", \"shape\": [{dims}]}}], \
                 \"meta\": {{\"n\": {i}, \"f\": {f}}}}}",
                dims = dims.join(","),
                f = rng.next_f64()
            ));
        }
        doc.push_str("]}");
        let j = Json::parse(&doc).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{doc}"));
        assert_eq!(j.get("artifacts").unwrap().as_arr().unwrap().len(), n);
        // and the real manifest loader accepts it
        let lib = hlgpu::runtime::ArtifactLibrary::from_json(&doc, "/tmp".into()).unwrap();
        assert_eq!(lib.len(), n);
    }
}

// ------------------------------------------------------- reduce stage --

/// `HLGPU_REDUCE=host` and `HLGPU_REDUCE=device` are observationally
/// identical (up to reduction-order rounding) for random images, sizes
/// and angle counts, through every emulator pipeline — the property the
/// differential CI runs rely on.
#[test]
fn prop_host_and_device_reduce_observationally_identical() {
    use hlgpu::tracetransform::{
        random_phantom, set_default_reduce, DeviceChoice, GpuAuto, GpuDynamic, GpuManual,
        ReduceMode, TraceImpl,
    };
    // Serialize against anything else in this binary that might flip the
    // process-wide reduce override.
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());

    for seed in 0..8u64 {
        let mut rng = Prng::new(9100 + seed);
        let size = rng.usize_in(6, 18);
        let angles = rng.usize_in(2, 9);
        let img = random_phantom(size, 9200 + seed);
        let thetas = hlgpu::tracetransform::orientations(angles);

        let mut impls: Vec<Box<dyn TraceImpl>> = vec![
            Box::new(GpuAuto::on_device(DeviceChoice::Emulator).unwrap()),
            Box::new(GpuDynamic::on_device(DeviceChoice::Emulator).unwrap()),
            Box::new(GpuManual::on_device(DeviceChoice::Emulator).unwrap()),
        ];
        for im in impls.iter_mut() {
            let name = im.name();
            set_default_reduce(Some(ReduceMode::Host));
            let host = im.features(&img, &thetas).unwrap();
            set_default_reduce(Some(ReduceMode::Device));
            let dev = im.features(&img, &thetas).unwrap();
            set_default_reduce(None);
            assert_eq!(host.len(), dev.len(), "{name} seed {seed}");
            for (i, (h, d)) in host.iter().zip(&dev).enumerate() {
                assert!(
                    (h - d).abs() <= 1e-4 * h.abs().max(1.0),
                    "{name} seed {seed} (s={size}, a={angles}) feature {i}: host {h} vs device {d}"
                );
            }
        }
    }
    set_default_reduce(None);
}

// ---------------------------------------------------------- multi-device --

/// Sharded `features_batch` over random batch sizes, image sizes and
/// device counts — including runs where one member is pre-loaded with
/// phantom outstanding work so placement skews hard onto the others —
/// is bitwise identical to the single-device path.
#[test]
fn prop_sharded_splits_agree_with_single_device() {
    use hlgpu::driver::DeviceSet;
    use hlgpu::tracetransform::{
        orientations, random_phantom, DeviceChoice, GpuAuto, ShardMode, TraceImpl,
    };
    for seed in 0..6u64 {
        let mut rng = Prng::new(14_000 + seed);
        let size = rng.usize_in(8, 16);
        let n = rng.usize_in(2, 9);
        let nlanes = rng.usize_in(2, 4);
        let thetas = orientations(rng.usize_in(3, 8));
        let imgs: Vec<_> = (0..n)
            .map(|i| random_phantom(size, 14_500 + seed * 100 + i as u64))
            .collect();

        let mut single = GpuAuto::on_device(DeviceChoice::Emulator)
            .unwrap()
            .with_shard(Some(ShardMode::Off));
        let want = single.features_batch(&imgs, &thetas).unwrap();

        let set = DeviceSet::emulator(nlanes).unwrap();
        if rng.bool() {
            // Skew: member 0 looks saturated, chunks chase the others.
            set.place(1_000);
        }
        let mut multi = GpuAuto::on_set(set)
            .unwrap()
            .with_shard(Some(ShardMode::Auto));
        let got = multi.features_batch(&imgs, &thetas).unwrap();
        assert_eq!(got, want, "seed {seed} size {size} n {n} lanes {nlanes}");
    }
}

/// Per-member memory pools are fully isolated (traffic on one member
/// never moves a sibling's counters) and each member's cross-arena
/// accounting stays consistent: steals are a subset of cache reuse, the
/// cached gauges agree with each other, and draining all live buffers
/// leaves nothing outstanding.
#[test]
fn prop_per_member_arena_stats_isolated_and_consistent() {
    use hlgpu::coordinator::DeviceArray;
    use hlgpu::driver::DeviceSet;
    for seed in 0..8u64 {
        let mut rng = Prng::new(15_000 + seed);
        let set = DeviceSet::emulator(3).unwrap();
        let quiet: Vec<_> =
            (0..set.len()).map(|i| set.context(i).mem_stats().unwrap()).collect();

        let victim = rng.usize_in(0, set.len() - 1);
        let ctx = set.context(victim);
        let mut live: Vec<DeviceArray> = Vec::new();
        for _ in 0..24 {
            if rng.bool() || live.is_empty() {
                let n = rng.usize_in(1, 512);
                let arena = rng.usize_in(0, 3);
                let t = Tensor::from_f32(&rng.f32_vec(n, -1.0, 1.0), &[n]);
                live.push(DeviceArray::from_tensor_in(ctx, arena, &t).unwrap());
            } else {
                let idx = rng.usize_in(0, live.len() - 1);
                live.remove(idx).free().unwrap();
            }
        }
        // Directed cross-arena churn: park same-size blocks from one
        // arena, re-allocate them from another — served from the bins
        // (locally or stolen from the sibling), never fresh carving.
        let t = Tensor::from_f32(&rng.f32_vec(256, -1.0, 1.0), &[256]);
        let parked: Vec<DeviceArray> = (0..4)
            .map(|_| DeviceArray::from_tensor_in(ctx, 1, &t).unwrap())
            .collect();
        for a in parked {
            a.free().unwrap();
        }
        let before = ctx.mem_stats().unwrap();
        let restolen: Vec<DeviceArray> = (0..4)
            .map(|_| DeviceArray::from_tensor_in(ctx, 2, &t).unwrap())
            .collect();
        let after = ctx.mem_stats().unwrap();
        if ctx.memory().unwrap().policy() == hlgpu::driver::PoolPolicy::Cached {
            assert!(
                after.reuse_count >= before.reuse_count + 4,
                "seed {seed}: same-size churn must be served from the bins"
            );
        }
        for a in restolen {
            a.free().unwrap();
        }
        for a in live.drain(..) {
            a.free().unwrap();
        }

        let st = ctx.mem_stats().unwrap();
        assert_eq!(st.current_bytes, 0, "seed {seed}: everything was freed");
        assert_eq!(st.alloc_count, st.free_count, "seed {seed}");
        // Cross-arena steals are counted inside the reuse totals.
        assert!(st.stolen_bytes <= st.reuse_bytes, "seed {seed}");
        assert!(st.stolen_blocks <= st.reuse_count, "seed {seed}");
        // The cached gauges agree with each other and with eviction:
        // blocks and bytes park/leave together.
        assert_eq!(st.cached_bytes == 0, st.cached_blocks == 0, "seed {seed}");
        assert_eq!(st.evicted_bytes == 0, st.evicted_blocks == 0, "seed {seed}");

        // Member isolation: the two untouched members saw zero traffic.
        for i in 0..set.len() {
            if i == victim {
                continue;
            }
            let s = set.context(i).mem_stats().unwrap();
            assert_eq!(s.alloc_count, quiet[i].alloc_count, "seed {seed} member {i}");
            assert_eq!(s.h2d_count, quiet[i].h2d_count, "seed {seed} member {i}");
            assert_eq!(s.current_bytes, quiet[i].current_bytes, "seed {seed} member {i}");
        }
    }
}
