//! Serving-engine correctness suite (see `docs/serving.md`).
//!
//! Covers the batch former's boundaries (flush-by-count vs
//! flush-by-deadline, 1-image batches, mixed sizes), deadline handling
//! (admission rejection and queued expiry), bounded-queue backpressure,
//! drain-on-shutdown, per-tenant stats, and the observational-identity
//! guarantee: features served through the engine are bitwise identical
//! to a direct `features_batch` call on the same images.
//!
//! Timing-sensitive tests only ever assert *lower* bounds (a deadline
//! that has certainly passed, a margin that has certainly not), so a
//! slow CI machine cannot flake them.

use hlgpu::serve::{ServeConfig, Service};
use hlgpu::tracetransform::{
    orientations, random_phantom, DeviceChoice, GpuAuto, TraceImpl, FEATURE_COUNT,
};
use hlgpu::Error;

fn service(config: ServeConfig) -> Service {
    Service::new(DeviceChoice::Emulator, &orientations(5), config).unwrap()
}

#[test]
fn single_request_is_served_as_a_batch_of_one() {
    let svc = service(ServeConfig { max_delay_us: 1_000, ..ServeConfig::default() });
    let feats = svc.submit("t", random_phantom(10, 1)).unwrap().wait().unwrap();
    assert_eq!(feats.len(), FEATURE_COUNT);
    let st = svc.stats("t");
    assert_eq!((st.admitted, st.served, st.rejected, st.expired), (1, 1, 0, 0));
    assert_eq!(st.batches.counts()[0], 1, "served in a batch of exactly 1");
}

#[test]
fn flush_by_count_forms_full_batches() {
    // The delay is far beyond the test's lifetime, so the only way these
    // requests get served is the count trigger.
    let svc = service(ServeConfig {
        max_batch: 4,
        max_delay_us: 30_000_000,
        workers: 1,
        ..ServeConfig::default()
    });
    let tickets: Vec<_> = (0..4)
        .map(|i| svc.submit("t", random_phantom(10, 10 + i)).unwrap())
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let st = svc.stats("t");
    assert_eq!(st.served, 4);
    assert_eq!(st.batches.counts()[2], 4, "all four rode one 4-image batch");
}

#[test]
fn flush_by_deadline_serves_partial_batches() {
    // max_batch is unreachable; only the age trigger can flush.
    let svc = service(ServeConfig {
        max_batch: 64,
        max_delay_us: 2_000,
        ..ServeConfig::default()
    });
    let tickets: Vec<_> = (0..3)
        .map(|i| svc.submit("t", random_phantom(10, 20 + i)).unwrap())
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let st = svc.stats("t");
    assert_eq!(st.served, 3);
    assert_eq!(st.batches.total(), 3);
    assert_eq!(st.rejected + st.expired, 0);
}

#[test]
fn zero_budget_is_rejected_at_admission() {
    let svc = service(ServeConfig::default());
    let err = svc
        .submit_with_deadline("t", random_phantom(10, 30), 0)
        .unwrap_err();
    assert!(
        matches!(err, Error::DeadlineExceeded { waited_us: 0, budget_us: 0 }),
        "got {err}"
    );
    assert_eq!(err.status(), "ERROR_TIMEOUT");
    let st = svc.stats("t");
    assert_eq!((st.admitted, st.rejected), (0, 1));
}

#[test]
fn queued_requests_expire_before_launch() {
    // The formed batch flushes by age after 30 ms; the 1 ms-budget
    // request has certainly expired by then, the generous one has not.
    // The expiry drop must not take the rest of the batch down with it.
    let svc = service(ServeConfig {
        max_batch: 64,
        max_delay_us: 30_000,
        workers: 1,
        ..ServeConfig::default()
    });
    let doomed = svc
        .submit_with_deadline("t", random_phantom(10, 40), 1_000)
        .unwrap();
    let alive = svc
        .submit_with_deadline("t", random_phantom(10, 41), 30_000_000)
        .unwrap();
    let err = doomed.wait().unwrap_err();
    match err {
        Error::DeadlineExceeded { waited_us, budget_us } => {
            assert_eq!(budget_us, 1_000);
            assert!(waited_us > budget_us, "waited {waited_us} <= budget {budget_us}");
        }
        other => panic!("expected DeadlineExceeded, got {other}"),
    }
    alive.wait().unwrap();
    let st = svc.stats("t");
    assert_eq!((st.admitted, st.served, st.expired), (2, 1, 1));
}

#[test]
fn overload_sheds_and_bounds_the_queue() {
    // One worker held off by a 200 ms age trigger: the first four
    // submissions certainly fill the queue before any batch forms.
    let svc = service(ServeConfig {
        max_batch: 64,
        max_delay_us: 200_000,
        queue_capacity: 4,
        workers: 1,
        ..ServeConfig::default()
    });
    let tickets: Vec<_> = (0..4)
        .map(|i| svc.submit("t", random_phantom(10, 50 + i)).unwrap())
        .collect();
    let err = svc.submit("t", random_phantom(10, 54)).unwrap_err();
    assert!(
        matches!(err, Error::Overloaded { depth: 4, capacity: 4 }),
        "got {err}"
    );
    assert_eq!(err.status(), "ERROR_OUT_OF_RESOURCES");
    assert!(svc.queue_depth() <= 4, "queue stayed bounded");
    for t in tickets {
        t.wait().unwrap();
    }
    let st = svc.stats("t");
    assert_eq!((st.admitted, st.served, st.rejected), (4, 4, 1));
}

#[test]
fn mixed_sizes_form_separate_batches_without_blocking() {
    // Two interleaved size classes, each flushing on a count of 2; the
    // age trigger is unreachable, so serving proves the former split
    // them into per-size batches (a mixed batch would fall back to the
    // sequential path and still serve, but the histogram would show
    // batches of 4).
    let svc = service(ServeConfig {
        max_batch: 2,
        max_delay_us: 30_000_000,
        ..ServeConfig::default()
    });
    let mut tickets = Vec::new();
    for i in 0..2u64 {
        tickets.push(svc.submit("t", random_phantom(10, 60 + i)).unwrap());
        tickets.push(svc.submit("t", random_phantom(12, 60 + i)).unwrap());
    }
    for t in tickets {
        t.wait().unwrap();
    }
    let st = svc.stats("t");
    assert_eq!(st.served, 4);
    assert_eq!(st.batches.counts()[1], 4, "two 2-image batches, one per size");
}

#[test]
fn service_results_match_direct_batch_bitwise() {
    // The emulator is deterministic: the same images through the same
    // batched pipeline must produce bit-identical features whether
    // driven directly or through the serving engine.
    let thetas = orientations(5);
    let imgs: Vec<_> = (0..4).map(|i| random_phantom(12, 70 + i)).collect();
    let mut direct = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
    let want = direct.features_batch(&imgs, &thetas).unwrap();
    let svc = Service::new(
        DeviceChoice::Emulator,
        &thetas,
        ServeConfig {
            max_batch: imgs.len(),
            max_delay_us: 30_000_000,
            workers: 1,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let tickets: Vec<_> = imgs
        .iter()
        .map(|img| svc.submit("t", img.clone()).unwrap())
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        assert_eq!(t.wait().unwrap(), want[i], "image {i} diverged");
    }
}

#[test]
fn shutdown_drains_queued_work() {
    // Requests sitting on a long age trigger still get served when the
    // service shuts down: shutdown flushes every group before exit.
    let svc = service(ServeConfig {
        max_batch: 64,
        max_delay_us: 30_000_000,
        workers: 1,
        ..ServeConfig::default()
    });
    let tickets: Vec<_> = (0..3)
        .map(|i| svc.submit("t", random_phantom(10, 80 + i)).unwrap())
        .collect();
    svc.shutdown();
    for t in tickets {
        let feats = t.wait().unwrap();
        assert_eq!(feats.len(), FEATURE_COUNT);
    }
}

#[test]
fn drain_racing_submitters_strands_no_ticket() {
    // Regression: `submit` used to check the shutdown flag *before*
    // taking the queue lock, so a submission racing `drain` could
    // enqueue after the workers had observed empty-queue + shutdown and
    // exited — stranding that ticket unresolved. The flag is now raised
    // and checked under the queue lock: every accepted ticket resolves,
    // every refused submission gets the typed shutdown error.
    use std::sync::Arc;
    let svc = Arc::new(service(ServeConfig {
        max_batch: 8,
        max_delay_us: 100,
        queue_capacity: 256,
        workers: 2,
        ..ServeConfig::default()
    }));
    let submitter = {
        let svc = svc.clone();
        std::thread::spawn(move || {
            let img = random_phantom(10, 7);
            let mut tickets = Vec::new();
            for _ in 0..20_000 {
                match svc.submit("race", img.clone()) {
                    Ok(t) => tickets.push(t),
                    Err(Error::Overloaded { .. }) => continue,
                    // The drain landed: the refusal must be the typed
                    // shutdown error, and no later submit may succeed.
                    Err(e) => {
                        assert!(e.to_string().contains("shut down"), "got {e}");
                        break;
                    }
                }
            }
            tickets
        })
    };
    // Let the submitter build up steam, then drain concurrently.
    std::thread::sleep(std::time::Duration::from_millis(5));
    svc.drain();
    let tickets = submitter.join().unwrap();
    assert!(!tickets.is_empty(), "the submitter raced at least one ticket in");
    let accepted = tickets.len() as u64;
    let mut served = 0u64;
    for (i, t) in tickets.into_iter().enumerate() {
        // After drain() returns, every accepted ticket must already be
        // resolved — a feature vector or a typed error (expiry and
        // injected-fault outcomes are legitimate under CI chaos), never
        // stranded. `try_wait` is non-blocking: a stranded ticket shows
        // up as None, not as a hung test.
        match t.try_wait() {
            Some(Ok(feats)) => {
                assert_eq!(feats.len(), FEATURE_COUNT);
                served += 1;
            }
            Some(Err(_)) => {}
            None => panic!("ticket {i} was stranded unresolved by the drain race"),
        }
    }
    let st = svc.stats("race");
    assert_eq!(st.admitted, accepted, "every accepted ticket is on the books");
    assert_eq!(st.served, served, "ticket outcomes and stats agree");
    assert_eq!(
        st.served + st.expired + st.failed,
        accepted,
        "every admitted request reached a terminal outcome"
    );
}

#[test]
fn tenants_get_separate_stats() {
    let svc = service(ServeConfig { max_delay_us: 1_000, ..ServeConfig::default() });
    let mut tickets = Vec::new();
    for i in 0..2u64 {
        tickets.push(svc.submit("alice", random_phantom(10, 90 + i)).unwrap());
    }
    for i in 0..3u64 {
        tickets.push(svc.submit("bob", random_phantom(10, 95 + i)).unwrap());
    }
    let _ = svc.submit_with_deadline("bob", random_phantom(10, 99), 0);
    for t in tickets {
        t.wait().unwrap();
    }
    assert_eq!(svc.stats("alice").served, 2);
    assert_eq!(svc.stats("bob").served, 3);
    assert_eq!(svc.stats("bob").rejected, 1);
    assert_eq!(svc.stats("nobody"), Default::default());
    let total = svc.stats_total();
    assert_eq!((total.admitted, total.served, total.rejected), (5, 5, 1));
    assert_eq!(total.batches.total(), 5);
    assert_eq!(svc.all_stats().len(), 2);
}

// ---------------------------------------------------------- multi-device --

/// A `DeviceSet`-backed service (workers pinned round-robin onto the
/// members) serves features bitwise identical to a direct
/// `features_batch`, and attributes every served image to a member
/// through the set's per-device accounting.
#[test]
fn deviceset_service_matches_direct_and_accounts_per_member() {
    use hlgpu::driver::DeviceSet;
    let thetas = orientations(5);
    let imgs: Vec<_> = (0..8u64).map(|i| random_phantom(10, 300 + i)).collect();

    let mut direct = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
    let want = direct.features_batch(&imgs, &thetas).unwrap();

    let svc = Service::on_set(
        DeviceSet::emulator(2).unwrap(),
        &thetas,
        ServeConfig { max_batch: 2, max_delay_us: 500, workers: 2, ..ServeConfig::default() },
    )
    .unwrap();
    let tickets: Vec<_> = imgs.iter().map(|img| svc.submit("t", img.clone()).unwrap()).collect();
    for (i, t) in tickets.into_iter().enumerate() {
        assert_eq!(t.wait().unwrap(), want[i], "image {i} diverged through the set");
    }

    let set = svc.device_set().expect("a set-backed service exposes its DeviceSet");
    let total: u64 = set.stats().iter().map(|m| m.images).sum();
    assert_eq!(total, imgs.len() as u64, "every served image is attributed to a member");
}
