//! Chaos suite for the fault-injection plane (see `docs/faults.md`).
//!
//! Exercises the full loss-and-recovery story end to end: seeded fault
//! schedules replay deterministically; killing any member of a sharded
//! `DeviceSet` mid-batch still yields results bitwise identical to a
//! fault-free run; the serving engine under an injected device loss
//! resolves every admitted ticket and re-pins onto a healthy member; a
//! hung kernel trips the hang cap into a sticky `DeviceLost` that only
//! `Device::reset` clears.
//!
//! The fault plane is process-global, so every test serializes on
//! [`Chaos::begin`], which also resets plans, counters and sticky lost
//! marks on entry *and* on drop — a panicking test cannot leak faults
//! into its neighbors.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use hlgpu::driver::faults::{self, FaultPlan, FaultSite};
use hlgpu::driver::{Context, Device, DeviceSet, Health};
use hlgpu::serve::{ServeConfig, Service};
use hlgpu::tracetransform::{
    orientations, random_phantom, DeviceChoice, GpuAuto, ShardMode, TraceImpl,
};
use hlgpu::Error;

static CHAOS_LOCK: Mutex<()> = Mutex::new(());

/// Exclusive, self-cleaning access to the process-global fault plane.
struct Chaos {
    _guard: std::sync::MutexGuard<'static, ()>,
}

impl Chaos {
    fn begin() -> Chaos {
        let guard = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        faults::reset_all();
        Chaos { _guard: guard }
    }
}

impl Drop for Chaos {
    fn drop(&mut self) {
        faults::reset_all();
    }
}

/// Plan lifecycle: the `HLGPU_FAULTS` grammar parses into the same rules
/// the builder produces, installing arms the plane, and a lost mark is
/// sticky until `Device::reset` (here via the registry it drives).
#[test]
fn plan_lifecycle_and_sticky_loss() {
    let _c = Chaos::begin();
    const ORD: usize = 9_500;

    let parsed = FaultPlan::parse("launch@2:3, H2D@1:1").unwrap();
    let built = FaultPlan::new()
        .fail(FaultSite::Launch, 2, 3)
        .fail(FaultSite::H2d, 1, 1);
    assert_eq!(parsed.rules(), built.rules());
    let err = FaultPlan::parse("launch@x:1").unwrap_err();
    assert!(err.to_string().contains("HLGPU_FAULTS"), "got {err}");

    assert!(!faults::armed());
    faults::install(built);
    assert!(faults::armed());
    assert_eq!(faults::active_plan().unwrap().rules().len(), 2);
    faults::clear();
    assert!(!faults::armed());
    assert!(faults::active_plan().is_none());

    assert!(!faults::is_lost(ORD));
    faults::mark_lost(ORD);
    assert!(faults::is_lost(ORD));
    let err = faults::check_lost(ORD).unwrap_err();
    assert!(matches!(err, Error::DeviceLost(ORD)), "got {err}");
    assert!(err.is_device_loss() && !err.is_transient());
    // clear() disarms the plan but keeps the sticky mark; only the
    // reset path lets the ordinal back in.
    faults::clear();
    assert!(faults::is_lost(ORD));
    faults::reset_device(ORD);
    assert!(faults::check_lost(ORD).is_ok());
}

/// Same-seed determinism: a seeded schedule over a two-member set drives
/// the sharded batch to the same outcome — identical features or the
/// identical typed error — and the identical per-site injection counts,
/// every time it replays.
#[test]
fn same_seed_fault_schedules_replay_identically() {
    let _c = Chaos::begin();
    let thetas = orientations(5);
    let imgs: Vec<_> = (0..4).map(|i| random_phantom(10, 700 + i as u64)).collect();
    // Hang is covered separately (`hung_kernel_...`); drawing it here
    // would serialize a hang-cap wait into every seed.
    let sites = [
        FaultSite::Alloc,
        FaultSite::Launch,
        FaultSite::Sync,
        FaultSite::H2d,
        FaultSite::D2h,
    ];
    let probe = DeviceSet::emulator(2).unwrap();
    let ordinals = [probe.device(0).ordinal, probe.device(1).ordinal];
    drop(probe);

    let run = |seed: u64| {
        faults::reset_all();
        faults::install(FaultPlan::seeded(seed, &sites, &ordinals, 6, 3));
        let set = DeviceSet::emulator(2).unwrap();
        let mut engine = GpuAuto::on_set(set)
            .unwrap()
            .with_shard(Some(ShardMode::Auto));
        let outcome = engine.features_batch(&imgs, &thetas).map_err(|e| e.to_string());
        (outcome, faults::injection_counts())
    };
    for seed in 1..=6u64 {
        let first = run(seed);
        let second = run(seed);
        assert_eq!(first, second, "seed {seed} diverged between runs");
    }
}

/// Kill each member of a 4-device set mid-batch in turn: the sharded
/// batch retries the victim's shards on the survivors and stays bitwise
/// identical to a fault-free single-device run; the victim ends `Lost`,
/// excluded from placement, and every image is still attributed.
#[test]
fn killing_any_member_mid_batch_preserves_bitwise_results() {
    let _c = Chaos::begin();
    let thetas = orientations(6);
    let imgs: Vec<_> = (0..9).map(|i| random_phantom(10, 400 + i as u64)).collect();
    let mut single = GpuAuto::on_device(DeviceChoice::Emulator)
        .unwrap()
        .with_shard(Some(ShardMode::Off));
    let reference = single.features_batch(&imgs, &thetas).unwrap();

    for victim in 0..4 {
        faults::reset_all();
        let set = DeviceSet::emulator(4).unwrap();
        let ord = set.device(victim).ordinal;
        // 9 images over 4 members gives every lane at least one chunk,
        // so the victim's very first launch is the one that fires.
        faults::install(FaultPlan::new().fail(FaultSite::Launch, ord, 1));
        let mut sharded = GpuAuto::on_set(set.clone())
            .unwrap()
            .with_shard(Some(ShardMode::Auto));
        let got = sharded.features_batch(&imgs, &thetas).unwrap();
        assert_eq!(got, reference, "victim {victim}: results diverged from fault-free");
        assert_eq!(faults::injections(FaultSite::Launch, ord), 1, "victim {victim}");
        assert_eq!(set.health(victim), Health::Lost, "victim {victim}");
        let next = set.place(0);
        assert_ne!(next, victim, "lost member must be excluded from placement");
        set.complete(next, 0);
        let stats = set.stats();
        let total: u64 = stats.iter().map(|s| s.images).sum();
        assert_eq!(total, imgs.len() as u64, "victim {victim}: every image attributed");
        assert!(
            stats.iter().all(|s| s.outstanding == 0),
            "victim {victim}: all shards retired: {stats:?}"
        );
    }
}

/// Serving under an injected device loss: every admitted ticket resolves
/// with features bitwise identical to a direct run, nothing is lost, the
/// worker re-pins off the dead member within one batch, and the
/// `retried`/`failed_over` counters record the detour.
#[test]
fn serve_under_injected_device_loss_resolves_every_ticket() {
    let _c = Chaos::begin();
    let thetas = orientations(5);
    let imgs: Vec<_> = (0..8).map(|i| random_phantom(10, 500 + i as u64)).collect();
    let mut direct = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
    let want = direct.features_batch(&imgs, &thetas).unwrap();

    let set = DeviceSet::emulator(2).unwrap();
    let ord0 = set.device(0).ordinal;
    // The single worker pins onto member 0; its first launch kills it.
    faults::install(FaultPlan::new().fail(FaultSite::Launch, ord0, 1));
    let svc = Service::on_set(
        set.clone(),
        &thetas,
        ServeConfig {
            max_batch: 4,
            max_delay_us: 1_000,
            workers: 1,
            queue_capacity: 64,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let tickets: Vec<_> = imgs
        .iter()
        .map(|img| svc.submit_with_deadline("t", img.clone(), 30_000_000).unwrap())
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        assert_eq!(t.wait().unwrap(), want[i], "ticket {i} diverged or was dropped");
    }
    let st = svc.stats("t");
    assert_eq!(
        (st.admitted, st.served, st.failed, st.expired),
        (8, 8, 0, 0),
        "every admitted ticket served"
    );
    assert!(st.retried >= 1, "the failed batch was re-admitted: {st:?}");
    assert!(st.failed_over >= 1, "the worker re-pinned: {st:?}");
    assert_eq!(set.health(0), Health::Lost);
    let next = set.place(0);
    assert_ne!(next, 0, "lost member must be excluded from placement");
    set.complete(next, 0);
}

/// A kernel that never completes trips the hang cap: the launch resolves
/// as a sticky `DeviceLost` in bounded time instead of wedging the
/// worker, subsequent calls fail fast, and `Device::reset` brings the
/// device back to bitwise-identical service.
#[test]
fn hung_kernel_trips_the_hang_cap_and_reset_recovers() {
    let _c = Chaos::begin();
    const ORD: usize = 9_400;
    let thetas = orientations(5);
    let imgs: Vec<_> = (0..2).map(|i| random_phantom(10, 600 + i as u64)).collect();
    let mut single = GpuAuto::on_device(DeviceChoice::Emulator)
        .unwrap()
        .with_shard(Some(ShardMode::Off));
    let want = single.features_batch(&imgs, &thetas).unwrap();

    let ctx = Context::create(&Device::emulator_at(ORD, None)).unwrap();
    let mut engine = GpuAuto::on_context(ctx.clone())
        .unwrap()
        .with_shard(Some(ShardMode::Off));
    faults::install(FaultPlan::new().fail(FaultSite::Hang, ORD, 1));

    let started = Instant::now();
    let err = engine.features_batch(&imgs, &thetas).unwrap_err();
    assert!(err.is_device_loss(), "hang must resolve as a device loss, got {err}");
    // The default hang cap is 1.5 s; anything wedged would sit here far
    // longer. Generous bound so a loaded CI machine cannot flake it.
    assert!(started.elapsed() < Duration::from_secs(60), "hang was not unwedged");
    assert!(faults::is_lost(ORD));

    let fast = Instant::now();
    let err = engine.features_batch(&imgs, &thetas).unwrap_err();
    assert!(err.is_device_loss(), "lost device must fail fast, got {err}");
    assert!(fast.elapsed() < Duration::from_secs(60));

    faults::clear();
    ctx.device().reset();
    assert!(!faults::is_lost(ORD));
    let got = engine.features_batch(&imgs, &thetas).unwrap();
    assert_eq!(got, want, "post-reset results must match the fault-free run");
}
