//! Cross-implementation and cross-backend differential tests: all five
//! trace-transform implementations and both execution backends must agree
//! on the feature vector for a variety of inputs — the repository's
//! strongest correctness signal (it exercises L1 Pallas artifacts, the
//! VTX emulator, the driver, the coordinator and the native algorithms in
//! one assertion).

use hlgpu::runtime::ArtifactLibrary;
use hlgpu::tracetransform::{
    feature_order, orientations, random_phantom, shepp_logan, AutoMode, CpuDynamic, CpuNative,
    DeviceChoice, GpuAuto, GpuDynamic, GpuManual, Image, TraceImpl, FEATURE_COUNT,
};

fn have_artifacts() -> bool {
    ArtifactLibrary::load_default().is_ok()
}

fn assert_close(name: &str, got: &[f32], want: &[f32], rel: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length");
    let order = feature_order();
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= rel * w.abs().max(1.0),
            "{name}: feature {i} {:?}: {g} vs {w}",
            order[i]
        );
    }
}

#[test]
fn all_emulator_impls_agree_on_random_phantoms() {
    let thetas = orientations(12);
    for seed in 0..4u64 {
        let img = random_phantom(20, seed);
        let want = CpuNative::new().features(&img, &thetas).unwrap();
        assert_eq!(want.len(), FEATURE_COUNT);

        let dynamic = CpuDynamic::new().features(&img, &thetas).unwrap();
        assert_close("cpu-dynamic", &dynamic, &want, 1e-3);

        let manual = GpuManual::on_device(DeviceChoice::Emulator)
            .unwrap()
            .features(&img, &thetas)
            .unwrap();
        assert_close("gpu-manual@emu", &manual, &want, 2e-3);

        let gd = GpuDynamic::on_device(DeviceChoice::Emulator)
            .unwrap()
            .features(&img, &thetas)
            .unwrap();
        assert_close("gpu-dynamic@emu", &gd, &want, 2e-3);

        let auto = GpuAuto::on_device(DeviceChoice::Emulator)
            .unwrap()
            .features(&img, &thetas)
            .unwrap();
        assert_close("gpu-auto@emu", &auto, &want, 2e-3);
    }
}

#[test]
fn pjrt_impls_agree_with_native_on_artifact_sizes() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let thetas = orientations(90);
    for size in [16usize, 32, 64] {
        let img = shepp_logan(size);
        let want = CpuNative::new().features(&img, &thetas).unwrap();

        for (name, mut im) in [
            (
                "gpu-manual",
                Box::new(GpuManual::on_device(DeviceChoice::Pjrt).unwrap())
                    as Box<dyn TraceImpl>,
            ),
            ("gpu-dynamic", Box::new(GpuDynamic::on_device(DeviceChoice::Pjrt).unwrap())),
            ("gpu-auto", Box::new(GpuAuto::on_device(DeviceChoice::Pjrt).unwrap())),
        ] {
            let got = im.features(&img, &thetas).unwrap();
            assert_close(&format!("{name}@pjrt s={size}"), &got, &want, 2e-3);
        }
    }
}

#[test]
fn auto_modes_agree_with_each_other() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let img = shepp_logan(32);
    let thetas = orientations(90);
    let fused_all = GpuAuto::on_device(DeviceChoice::Pjrt)
        .unwrap()
        .features(&img, &thetas)
        .unwrap();
    let staged = GpuAuto::on_device(DeviceChoice::Pjrt)
        .unwrap()
        .with_mode(AutoMode::PerFunctional)
        .features(&img, &thetas)
        .unwrap();
    assert_close("staged-vs-all", &staged, &fused_all, 1e-3);

    // trace_full computes P/F on device too — feature order must line up
    let full = GpuAuto::fused().unwrap().features(&img, &thetas).unwrap();
    assert_close("trace_full-vs-all", &full, &fused_all, 2e-3);
}

#[test]
fn degenerate_images_handled_everywhere() {
    let thetas = orientations(8);
    // blank image: all linear functionals 0; max-based features finite
    let blank = Image::zeros(16);
    let native = CpuNative::new().features(&blank, &thetas).unwrap();
    assert!(native.iter().all(|f| f.is_finite()));
    let emu = GpuAuto::on_device(DeviceChoice::Emulator)
        .unwrap()
        .features(&blank, &thetas)
        .unwrap();
    assert_close("blank@emu", &emu, &native, 1e-4);

    // constant image
    let mut flat = Image::zeros(16);
    flat.pixels_mut().fill(0.5);
    let native = CpuNative::new().features(&flat, &thetas).unwrap();
    let dynamic = CpuDynamic::new().features(&flat, &thetas).unwrap();
    assert_close("flat dynamic", &dynamic, &native, 1e-3);
}

#[test]
fn single_orientation_works() {
    let img = shepp_logan(16);
    let thetas = vec![0.0f32];
    let native = CpuNative::new().features(&img, &thetas).unwrap();
    let emu = GpuAuto::on_device(DeviceChoice::Emulator)
        .unwrap()
        .features(&img, &thetas)
        .unwrap();
    assert_close("single-angle", &emu, &native, 1e-3);
}
