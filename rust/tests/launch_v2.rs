//! Launch API v2 integration suite: bound kernel handles, device-resident
//! arguments, stream-ordered async launches (see `docs/api.md`).
//!
//! The acceptance regression lives here: a warm `KernelHandle` launch
//! with all-device-resident arguments performs **zero** h2d/d2h copies
//! and **zero** specialization-cache lookups, asserted against
//! `LaunchMetrics` and `MemStats`.

use std::sync::Mutex;

use hlgpu::coordinator::{arg, DeviceArray, Launcher, VtxSpec};
use hlgpu::driver::{emulator_device, Context, KernelArg, LaunchConfig};
use hlgpu::tensor::{Dtype, Tensor};

/// Guards the process-wide execution-tier override.
static EXEC_LOCK: Mutex<()> = Mutex::new(());

fn vadd_launcher() -> Launcher {
    let mut l = Launcher::emulator().unwrap();
    l.registry_mut().register_vtx("vadd", |specs| {
        let n = specs[0].numel();
        Ok(VtxSpec {
            kernel: hlgpu::emulator::kernels::vadd()?,
            scalars: vec![KernelArg::I32(n as i32)],
            config: LaunchConfig::new((n as u32).div_ceil(256), 256u32),
        })
    });
    l
}

// ------------------------------------------------- acceptance criterion --

#[test]
fn warm_device_resident_handle_launch_is_zero_copy_zero_lookup() {
    let mut l = vadd_launcher();
    let ctx = l.context().clone();
    let a = Tensor::from_f32(&[1.0; 64], &[64]);
    let b = Tensor::from_f32(&[2.0; 64], &[64]);
    let da = DeviceArray::from_tensor(&ctx, &a).unwrap();
    let db = DeviceArray::from_tensor(&ctx, &b).unwrap();
    let mut dc = DeviceArray::alloc(&ctx, Dtype::F32, &[64]).unwrap();
    let handle = l
        .bind("vadd", &[arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)])
        .unwrap();
    let cfg = LaunchConfig::new(1u32, 64u32);
    // one warm-up launch, then measure a steady-state window
    handle
        .launch(cfg, &mut [arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)])
        .unwrap();
    ctx.memory().unwrap().reset_stats();
    let cache_before = l.cache_stats();
    let m_before = l.metrics();
    for _ in 0..25 {
        handle
            .launch(cfg, &mut [arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)])
            .unwrap();
    }
    let st = ctx.mem_stats().unwrap();
    assert_eq!(st.h2d_count, 0, "zero host->device copies");
    assert_eq!(st.d2h_count, 0, "zero device->host copies");
    assert_eq!(st.alloc_count, 0, "zero allocator traffic");
    let cache_after = l.cache_stats();
    assert_eq!(cache_before.hits, cache_after.hits, "zero cache lookups");
    assert_eq!(cache_before.misses, cache_after.misses, "zero cache misses");
    let m = l.metrics();
    assert_eq!(m.launches - m_before.launches, 25);
    assert_eq!(m.skipped_h2d - m_before.skipped_h2d, 75, "3 skipped uploads per launch");
    assert_eq!(m.skipped_d2h - m_before.skipped_d2h, 25, "1 skipped download per launch");
    assert!(dc.download().unwrap().as_f32().iter().all(|&v| v == 3.0));
}

// ------------------------------------------- device-resident chaining --

#[test]
fn device_resident_chaining_identical_across_exec_tiers() {
    use hlgpu::emulator::{set_default_exec, ExecTier};
    let _g = EXEC_LOCK.lock().unwrap();
    let mut per_tier = Vec::new();
    for tier in [ExecTier::Scalar, ExecTier::Vector] {
        set_default_exec(Some(tier));
        let mut l = vadd_launcher();
        let ctx = l.context().clone();
        let n = 128usize;
        let a = Tensor::from_f32(&(0..n).map(|i| i as f32).collect::<Vec<_>>(), &[n]);
        let b = Tensor::from_f32(&(0..n).map(|i| (i * 2) as f32).collect::<Vec<_>>(), &[n]);
        let cfg = LaunchConfig::new(1u32, n as u32);
        // device-resident chain: a+b -> c, c+a -> d; no host round-trip
        let da = DeviceArray::from_tensor(&ctx, &a).unwrap();
        let db = DeviceArray::from_tensor(&ctx, &b).unwrap();
        let mut dc = DeviceArray::alloc(&ctx, Dtype::F32, &[n]).unwrap();
        let mut dd = DeviceArray::alloc(&ctx, Dtype::F32, &[n]).unwrap();
        l.launch("vadd", cfg, &mut [arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)])
            .unwrap();
        l.launch("vadd", cfg, &mut [arg::cu_dev(&dc), arg::cu_dev(&da), arg::cu_dev_mut(&mut dd)])
            .unwrap();
        let chained = dd.download().unwrap().to_vec_f32();
        // the chained stages really skipped the host
        let m = l.metrics();
        assert_eq!(m.skipped_h2d, 6);
        assert_eq!(m.skipped_d2h, 2);
        // host round-trip reference through the same launcher
        let mut c = Tensor::zeros_f32(&[n]);
        let mut d = Tensor::zeros_f32(&[n]);
        l.launch("vadd", cfg, &mut [arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)])
            .unwrap();
        l.launch("vadd", cfg, &mut [arg::cu_in(&c), arg::cu_in(&a), arg::cu_out(&mut d)])
            .unwrap();
        assert_eq!(chained, d.to_vec_f32(), "chain == round-trip under {tier:?}");
        per_tier.push(chained);
    }
    set_default_exec(None);
    assert_eq!(per_tier[0], per_tier[1], "scalar and vector tiers agree bitwise");
}

// --------------------------------------------- stream-ordered launches --

#[test]
fn pending_launch_event_orders_two_streams() {
    let mut l = vadd_launcher();
    let ctx = l.context().clone();
    let n = 4096usize;
    let a = Tensor::from_f32(&vec![1.5; n], &[n]);
    let b = Tensor::from_f32(&vec![2.5; n], &[n]);
    let da = DeviceArray::from_tensor(&ctx, &a).unwrap();
    let db = DeviceArray::from_tensor(&ctx, &b).unwrap();
    let mut dc = DeviceArray::alloc(&ctx, Dtype::F32, &[n]).unwrap();
    let mut dd = DeviceArray::alloc(&ctx, Dtype::F32, &[n]).unwrap();
    let handle = l
        .bind("vadd", &[arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)])
        .unwrap();
    let s1 = ctx.create_stream().unwrap();
    let s2 = ctx.create_stream().unwrap();
    let cfg = LaunchConfig::new((n as u32).div_ceil(256), 256u32);
    let p1 = handle
        .launch_on(&s1, cfg, &mut [arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)])
        .unwrap();
    // fence stream 2 on stream 1's launch, then chain off its output
    s2.wait_event(p1.event()).unwrap();
    let p2 = handle
        .launch_on(&s2, cfg, &mut [arg::cu_dev(&dc), arg::cu_dev(&da), arg::cu_dev_mut(&mut dd)])
        .unwrap();
    p2.wait().unwrap();
    p1.wait().unwrap();
    let out = dd.download().unwrap();
    // d = (a + b) + a = 1.5 + 2.5 + 1.5
    assert!(out.as_f32().iter().all(|&v| v == 5.5));
}

#[test]
fn async_launch_with_host_inputs_uploads_in_order() {
    let mut l = vadd_launcher();
    let ctx = l.context().clone();
    let a = Tensor::from_f32(&[4.0; 32], &[32]);
    let b = Tensor::from_f32(&[5.0; 32], &[32]);
    let mut dc = DeviceArray::alloc(&ctx, Dtype::F32, &[32]).unwrap();
    let handle = l
        .bind("vadd", &[arg::cu_in(&a), arg::cu_in(&b), arg::cu_dev_mut(&mut dc)])
        .unwrap();
    let s = ctx.create_stream().unwrap();
    let p = handle
        .launch_on(
            &s,
            LaunchConfig::new(1u32, 32u32),
            &mut [arg::cu_in(&a), arg::cu_in(&b), arg::cu_dev_mut(&mut dc)],
        )
        .unwrap();
    p.wait().unwrap();
    assert!(dc.download().unwrap().as_f32().iter().all(|&v| v == 9.0));
}

#[test]
fn back_to_back_async_launches_keep_host_inputs_ordered() {
    // Regression: the staging buffer for a host `In` argument is shared
    // by every launch through a handle. The second launch_on's upload
    // must be stream-ordered AFTER the first kernel, not performed
    // eagerly on the host (which would overwrite the input kernel 1
    // reads).
    let mut l = vadd_launcher();
    let ctx = l.context().clone();
    let zeros = Tensor::from_f32(&[0.0; 32], &[32]);
    let x1 = Tensor::from_f32(&[1.0; 32], &[32]);
    let x2 = Tensor::from_f32(&[100.0; 32], &[32]);
    let mut d1 = DeviceArray::alloc(&ctx, Dtype::F32, &[32]).unwrap();
    let mut d2 = DeviceArray::alloc(&ctx, Dtype::F32, &[32]).unwrap();
    let handle = l
        .bind("vadd", &[arg::cu_in(&x1), arg::cu_in(&zeros), arg::cu_dev_mut(&mut d1)])
        .unwrap();
    let s = ctx.create_stream().unwrap();
    let cfg = LaunchConfig::new(1u32, 32u32);
    let p1 = handle
        .launch_on(&s, cfg, &mut [arg::cu_in(&x1), arg::cu_in(&zeros), arg::cu_dev_mut(&mut d1)])
        .unwrap();
    let p2 = handle
        .launch_on(&s, cfg, &mut [arg::cu_in(&x2), arg::cu_in(&zeros), arg::cu_dev_mut(&mut d2)])
        .unwrap();
    p1.wait().unwrap();
    p2.wait().unwrap();
    assert!(d1.download().unwrap().as_f32().iter().all(|&v| v == 1.0), "kernel 1 saw x1");
    assert!(d2.download().unwrap().as_f32().iter().all(|&v| v == 100.0), "kernel 2 saw x2");
}

#[test]
fn cloned_handles_serialize_host_staging_across_threads() {
    // Regression: synchronous launches through cloned handles share one
    // staging plan; the per-specialization stage lock must keep two
    // threads from interleaving upload/launch/download on it.
    let mut l = vadd_launcher();
    let handle = {
        let a = Tensor::from_f32(&[0.0; 64], &[64]);
        let b = Tensor::from_f32(&[0.0; 64], &[64]);
        let mut c = Tensor::zeros_f32(&[64]);
        l.bind("vadd", &[arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)]).unwrap()
    };
    let cfg = LaunchConfig::new(1u32, 64u32);
    let mut workers = Vec::new();
    for t in 0..4u32 {
        let h = handle.clone();
        workers.push(std::thread::spawn(move || {
            for i in 0..50u32 {
                let va = (t * 1000 + i) as f32;
                let a = Tensor::from_f32(&[va; 64], &[64]);
                let b = Tensor::from_f32(&[0.5; 64], &[64]);
                let mut c = Tensor::zeros_f32(&[64]);
                h.launch(cfg, &mut [arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)])
                    .unwrap();
                assert!(
                    c.as_f32().iter().all(|&v| v == va + 0.5),
                    "thread {t} iter {i}: staging interleaved"
                );
            }
        }));
    }
    for w in workers {
        w.join().unwrap();
    }
}

#[test]
fn handle_rejects_type_punned_arguments() {
    // Regression: the handle path has no cache key, so validation must
    // catch an i32 tensor passed where the plan was built for f32 of
    // the same byte length.
    let mut l = vadd_launcher();
    let a = Tensor::from_f32(&[1.0; 16], &[16]);
    let b = Tensor::from_f32(&[2.0; 16], &[16]);
    let mut c = Tensor::zeros_f32(&[16]);
    let handle = l
        .bind("vadd", &[arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)])
        .unwrap();
    let cfg = LaunchConfig::new(1u32, 16u32);
    // same 64 bytes, wrong dtype
    let punned = Tensor::new(
        hlgpu::tensor::Dtype::I32,
        &[16],
        vec![0u8; 64],
    )
    .unwrap();
    let err = handle
        .launch(cfg, &mut [arg::cu_in(&punned), arg::cu_in(&b), arg::cu_out(&mut c)])
        .unwrap_err();
    assert!(err.to_string().contains("specialized for"), "{err}");
    // same byte length, different shape
    let reshaped = Tensor::from_f32(&[1.0; 16], &[4, 4]);
    let err = handle
        .launch(cfg, &mut [arg::cu_in(&reshaped), arg::cu_in(&b), arg::cu_out(&mut c)])
        .unwrap_err();
    assert!(err.to_string().contains("specialized for"), "{err}");
}

#[test]
fn launch_on_rejects_host_outputs() {
    let mut l = vadd_launcher();
    let a = Tensor::from_f32(&[1.0; 8], &[8]);
    let b = Tensor::from_f32(&[1.0; 8], &[8]);
    let mut c = Tensor::zeros_f32(&[8]);
    let handle = l
        .bind("vadd", &[arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)])
        .unwrap();
    let s = l.context().create_stream().unwrap();
    let err = handle
        .launch_on(
            &s,
            LaunchConfig::new(1u32, 8u32),
            &mut [arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)],
        )
        .unwrap_err();
    assert!(err.to_string().contains("device-resident"), "{err}");
}

#[test]
fn sticky_stream_errors_surface_on_wait() {
    let mut l = vadd_launcher();
    let ctx = l.context().clone();
    let a = Tensor::from_f32(&[1.0; 16], &[16]);
    let da = DeviceArray::from_tensor(&ctx, &a).unwrap();
    let db = DeviceArray::from_tensor(&ctx, &a).unwrap();
    let mut dc = DeviceArray::alloc(&ctx, Dtype::F32, &[16]).unwrap();
    let handle = l
        .bind("vadd", &[arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)])
        .unwrap();
    let s = ctx.create_stream().unwrap();
    // poison the stream before the launch: CUDA's sticky-error model
    // surfaces the earlier failure at the join point
    s.enqueue(|| Err(hlgpu::Error::Stream("poisoned upstream".into()))).unwrap();
    let p = handle
        .launch_on(
            &s,
            LaunchConfig::new(1u32, 16u32),
            &mut [arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)],
        )
        .unwrap();
    let err = p.wait().unwrap_err();
    assert!(err.to_string().contains("poisoned upstream"), "{err}");
    // the stream kept draining: the launch after the poison still ran
    assert!(dc.download().unwrap().as_f32().iter().all(|&v| v == 2.0));
}

// --------------------------------------------- async d2h readbacks --

#[test]
fn pending_download_is_stream_ordered_after_the_kernel() {
    let mut l = vadd_launcher();
    let ctx = l.context().clone();
    let a = Tensor::from_f32(&[1.0; 64], &[64]);
    let b = Tensor::from_f32(&[2.0; 64], &[64]);
    let da = DeviceArray::from_tensor(&ctx, &a).unwrap();
    let db = DeviceArray::from_tensor(&ctx, &b).unwrap();
    let mut dc = DeviceArray::alloc(&ctx, Dtype::F32, &[64]).unwrap();
    let handle = l
        .bind("vadd", &[arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)])
        .unwrap();
    let s = ctx.create_stream().unwrap();
    let cfg = LaunchConfig::new(1u32, 64u32);
    // enqueue kernel then download on the same stream: no host sync in
    // between — FIFO order makes the download observe the kernel
    handle
        .launch_on(&s, cfg, &mut [arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)])
        .unwrap();
    let pd = handle.download_on(&s, &dc).unwrap();
    let t = pd.wait().unwrap();
    assert_eq!(t.shape(), &[64]);
    assert!(t.as_f32().iter().all(|&v| v == 3.0));
    // the deferred readback is visible in the metrics
    let m = l.metrics();
    assert_eq!(m.d2h_deferred, 1);
    assert_eq!(m.features_bytes, 64 * 4);
}

#[test]
fn pending_download_chains_across_streams_via_events() {
    let mut l = vadd_launcher();
    let ctx = l.context().clone();
    let n = 2048usize;
    let a = Tensor::from_f32(&vec![1.25; n], &[n]);
    let b = Tensor::from_f32(&vec![0.75; n], &[n]);
    let da = DeviceArray::from_tensor(&ctx, &a).unwrap();
    let db = DeviceArray::from_tensor(&ctx, &b).unwrap();
    let mut dc = DeviceArray::alloc(&ctx, Dtype::F32, &[n]).unwrap();
    let handle = l
        .bind("vadd", &[arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)])
        .unwrap();
    let compute = ctx.create_stream().unwrap();
    let download = ctx.create_stream().unwrap();
    let cfg = LaunchConfig::new((n as u32).div_ceil(256), 256u32);
    let p = handle
        .launch_on(
            &compute,
            cfg,
            &mut [arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)],
        )
        .unwrap();
    // fence the download stream on the launch, then read back there
    download.wait_event(p.event()).unwrap();
    let pd = dc.download_on(&download).unwrap();
    let t = pd.wait().unwrap();
    assert!(t.as_f32().iter().all(|&v| v == 2.0));
    assert!(download.is_idle(), "wait() joins the download stream's work");
    p.wait().unwrap();
}

#[test]
fn pending_download_surfaces_sticky_stream_errors() {
    let l = vadd_launcher();
    let ctx = l.context().clone();
    let t = Tensor::from_f32(&[5.0; 16], &[16]);
    let d = DeviceArray::from_tensor(&ctx, &t).unwrap();
    let s = ctx.create_stream().unwrap();
    s.enqueue(|| Err(hlgpu::Error::Stream("poisoned before readback".into()))).unwrap();
    let pd = d.download_on(&s).unwrap();
    let err = pd.wait().unwrap_err();
    assert!(err.to_string().contains("poisoned before readback"), "{err}");
    // a fresh download on a clean stream still works
    s.synchronize().unwrap_err(); // consume the sticky error
    let pd = d.download_on(&s).unwrap();
    assert_eq!(pd.wait().unwrap().as_f32(), t.as_f32());
}

// ------------------------------------------------- per-stream arenas --

#[test]
fn stream_arenas_partition_the_pool() {
    let ctx = Context::create(&emulator_device().unwrap()).unwrap();
    let s1 = ctx.create_stream().unwrap();
    let s2 = ctx.create_stream().unwrap();
    assert_ne!(s1.arena_id(), s2.arena_id());
    let p1 = ctx.alloc_in(s1.arena_id(), 256).unwrap();
    let p2 = ctx.alloc_in(s2.arena_id(), 256).unwrap();
    let n = ctx.memory().unwrap().arena_count() as u64;
    // handles encode their arena (seq * arenas + arena); nonzero stream
    // ids spread over shards 1..n, never the default arena 0
    let expect = |id: u64| if n == 1 { 0 } else { 1 + (id - 1) % (n - 1) };
    assert_eq!(p1.0 % n, expect(s1.arena_id() as u64));
    assert_eq!(p2.0 % n, expect(s2.arena_id() as u64));
    if n > 1 {
        assert_ne!(p1.0 % n, 0, "stream buffers avoid the synchronous arena");
    }
    ctx.free(p1).unwrap();
    ctx.free(p2).unwrap();
}

// ------------------------------------------- end-to-end batched pipeline --

#[test]
fn two_stream_batched_pipeline_matches_sequential() {
    use hlgpu::tracetransform::{orientations, random_phantom, DeviceChoice, GpuAuto, TraceImpl};
    let imgs: Vec<_> = (0..6).map(|i| random_phantom(12, 500 + i as u64)).collect();
    let thetas = orientations(7);
    let mut auto = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
    let batch = auto.features_batch(&imgs, &thetas).unwrap();
    // repeat to exercise the warm (buffer-reusing) path too
    let batch2 = auto.features_batch(&imgs, &thetas).unwrap();
    assert_eq!(batch, batch2);
    for (i, img) in imgs.iter().enumerate() {
        let seq = auto.features(img, &thetas).unwrap();
        for (j, (x, y)) in batch[i].iter().zip(&seq).enumerate() {
            assert!(
                (x - y).abs() < 1e-4 * x.abs().max(1.0),
                "image {i} feature {j}: batch {x} vs seq {y}"
            );
        }
    }
}

// ----------------------------------------- multi-device handle migration --

/// A vadd launcher bound to a caller-supplied context (a `DeviceSet`
/// member) instead of the process-default emulator device.
fn vadd_launcher_on(ctx: Context) -> Launcher {
    let mut l = Launcher::new(ctx, hlgpu::coordinator::KernelRegistry::new(None));
    l.registry_mut().register_vtx("vadd", |specs| {
        let n = specs[0].numel();
        Ok(VtxSpec {
            kernel: hlgpu::emulator::kernels::vadd()?,
            scalars: vec![KernelArg::I32(n as i32)],
            config: LaunchConfig::new((n as u32).div_ceil(256), 256u32),
        })
    });
    l
}

/// `KernelHandle::migrate_to` rebinds a specialized handle onto another
/// set member; re-run against migrated arrays it reproduces the origin
/// device's results bitwise. Feeding the migrated handle an array that
/// still lives on the origin device names both ordinals and the
/// offending argument index.
#[test]
fn migrated_handle_matches_origin_and_names_ordinals_on_mixups() {
    use hlgpu::driver::DeviceSet;
    use hlgpu::error::Error;

    let set = DeviceSet::emulator(2).unwrap();
    let mut src = vadd_launcher_on(set.context(0).clone());
    let mut dst = vadd_launcher_on(set.context(1).clone());

    let n = 300usize;
    let a: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
    let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos()).collect();
    let ta = Tensor::from_f32(&a, &[n]);
    let tb = Tensor::from_f32(&b, &[n]);

    let da = DeviceArray::from_tensor(set.context(0), &ta).unwrap();
    let db = DeviceArray::from_tensor(set.context(0), &tb).unwrap();
    let mut dc = DeviceArray::alloc(set.context(0), Dtype::F32, &[n]).unwrap();

    let h = src
        .bind("vadd", &[arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)])
        .unwrap();
    let cfg = LaunchConfig::new((n as u32).div_ceil(256), 256u32);
    h.launch(cfg, &mut [arg::cu_dev(&da), arg::cu_dev(&db), arg::cu_dev_mut(&mut dc)])
        .unwrap();
    let want = dc.download().unwrap().as_f32().to_vec();

    // Migrating onto the same context is a preflight no-op (clone).
    assert!(h.migrate_to(&mut src).is_ok());

    // Cross-device: migrate the handle and its operands, then re-run.
    let h2 = h.migrate_to(&mut dst).unwrap();
    let ma = da.migrate_to(set.context(1)).unwrap();
    let mb = db.migrate_to(set.context(1)).unwrap();
    let mut mc = DeviceArray::alloc(set.context(1), Dtype::F32, &[n]).unwrap();
    h2.launch(cfg, &mut [arg::cu_dev(&ma), arg::cu_dev(&mb), arg::cu_dev_mut(&mut mc)])
        .unwrap();
    assert_eq!(mc.download().unwrap().as_f32(), want.as_slice());

    // Mixed-context launch: argument 0 still lives on member 0.
    let err = h2
        .launch(cfg, &mut [arg::cu_dev(&da), arg::cu_dev(&mb), arg::cu_dev_mut(&mut mc)])
        .unwrap_err();
    let (o0, o1) = (set.device(0).ordinal, set.device(1).ordinal);
    match err {
        Error::BadArgument { index, reason, .. } => {
            assert_eq!(index, 0);
            assert!(reason.contains("different context"), "{reason}");
            assert!(reason.contains(&format!("lives on device {o0}")), "{reason}");
            assert!(reason.contains(&format!("targets device {o1}")), "{reason}");
        }
        other => panic!("expected BadArgument, got {other:?}"),
    }
}
