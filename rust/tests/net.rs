//! Networked-serving suite: the framed wire protocol and the TCP front
//! door end to end over loopback (see `docs/wire.md`).
//!
//! Three axes of coverage:
//!
//! * **remote identity** — features served over a socket are bitwise
//!   identical to a direct `features_batch` on the same images, for
//!   single-device and `DeviceSet`-backed services, pipelined and mixed
//!   sizes, and under an injected device loss (the failover is invisible
//!   to the client except through the STATS snapshot).
//! * **protocol robustness** — garbage, truncated, unknown-type and
//!   partial-write streams produce one typed protocol error (wire code
//!   63) and a clean close, never a wedged or panicked server; the next
//!   connection is served normally.
//! * **lifecycle** — a client disconnecting mid-batch leaks nothing (the
//!   server still resolves every admitted ticket and the stats books
//!   balance), and a server shutdown drains every in-flight response to
//!   a still-connected client before closing.
//!
//! The fault-injection test serializes on a local chaos guard and
//! targets synthesized far ordinals, so it cannot perturb the parallel
//! tests (or be perturbed by an ambient `HLGPU_FAULTS` schedule).

use std::io::Write;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::Duration;

use hlgpu::driver::faults::{self, FaultPlan, FaultSite};
use hlgpu::driver::{device_count, Device, DeviceSet, Health};
use hlgpu::net::wire::{self, Frame, Pixels, WireFailure};
use hlgpu::net::{NetClient, NetConfig, NetServer, Received, VERSION};
use hlgpu::serve::{ServeConfig, Service};
use hlgpu::tracetransform::{
    orientations, random_phantom, DeviceChoice, GpuAuto, Image, TraceImpl,
};
use hlgpu::Error;

/// A generous per-request budget: these tests assert on outcomes, not
/// latency, and must not flake into `DeadlineExceeded` on a loaded CI
/// machine.
const DEADLINE_US: u64 = 30_000_000;

fn thetas() -> Vec<f32> {
    orientations(5)
}

fn serve_config() -> ServeConfig {
    ServeConfig {
        max_batch: 4,
        max_delay_us: 500,
        queue_capacity: 64,
        default_deadline_us: DEADLINE_US,
        workers: 2,
    }
}

fn server_on(config: ServeConfig) -> NetServer {
    let svc = Service::new(DeviceChoice::Emulator, &thetas(), config).unwrap();
    NetServer::bind("127.0.0.1:0", svc, NetConfig::default()).unwrap()
}

fn direct_features(imgs: &[Image]) -> Vec<Vec<f32>> {
    let mut engine = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
    engine.features_batch(imgs, &thetas()).unwrap()
}

fn direct_one(img: &Image) -> Vec<f32> {
    direct_features(std::slice::from_ref(img)).remove(0)
}

/// Raw-socket handshake for the malformed-stream tests: HELLO out,
/// WELCOME back, no client-layer machinery in the way.
fn raw_handshake(addr: std::net::SocketAddr, tenant: &str) -> TcpStream {
    let mut raw = TcpStream::connect(addr).unwrap();
    let hello = Frame::Hello { version: VERSION, tenant: tenant.to_string() };
    wire::write_frame(&mut raw, &hello).unwrap();
    raw.flush().unwrap();
    let frame = wire::read_frame(&mut raw, u32::MAX).unwrap();
    assert!(matches!(frame, Some(Frame::Welcome { .. })), "expected WELCOME, got {frame:?}");
    raw
}

#[test]
fn handshake_and_single_request_match_direct_bitwise() {
    let server = server_on(serve_config());
    let addr = server.addr().to_string();
    let img = random_phantom(12, 4000);
    let want = direct_one(&img);

    let mut client = NetClient::connect(&addr, "tenant-a").unwrap();
    assert!(client.window() >= 1, "the server granted an in-flight window");
    let feats = client.features(&img, DEADLINE_US).unwrap();
    assert_eq!(feats, want, "remote features diverged from the direct run");

    let st = server.service().stats("tenant-a");
    assert_eq!(st.served, 1, "the request was accounted to the HELLO tenant: {st:?}");
    client.goodbye().unwrap();
    server.shutdown();
}

#[test]
fn pipelined_mixed_sizes_match_direct_bitwise() {
    let server = server_on(serve_config());
    let addr = server.addr().to_string();
    // Two interleaved size classes: the per-size batch former regroups
    // execution freely, but responses come back in submission order.
    let mut imgs = Vec::new();
    for i in 0..8u64 {
        let size = if i % 2 == 0 { 10 } else { 12 };
        imgs.push(random_phantom(size, 4100 + i));
    }
    let mut want = Vec::new();
    for img in &imgs {
        want.push(direct_one(img));
    }

    let mut client = NetClient::connect(&addr, "pipeline").unwrap();
    let mut ids = Vec::new();
    for img in &imgs {
        ids.push(client.submit(img, DEADLINE_US).unwrap());
    }
    for (i, &id) in ids.iter().enumerate() {
        let (got_id, outcome) = client.recv().unwrap();
        assert_eq!(got_id, id, "responses arrive in submission order");
        assert_eq!(outcome.unwrap(), want[i], "image {i} diverged over the wire");
    }
    client.goodbye().unwrap();
    server.shutdown();
}

#[test]
fn u8_payload_serves_the_quantized_image() {
    let server = server_on(serve_config());
    let addr = server.addr().to_string();
    let size = 10usize;
    let mut bytes = Vec::new();
    for i in 0..size * size {
        bytes.push((i * 7 % 256) as u8);
    }
    // The wire contract: u8 pixels decode as v / 255 — the direct run on
    // that reconstruction is the bitwise reference.
    let unit: Vec<f32> = bytes.iter().map(|&b| b as f32 / 255.0).collect();
    let want = direct_one(&Image::new(size, unit).unwrap());

    let mut client = NetClient::connect(&addr, "quant").unwrap();
    let id = client.submit_u8(size, bytes, DEADLINE_US).unwrap();
    let (got_id, outcome) = client.recv().unwrap();
    assert_eq!(got_id, id);
    assert_eq!(outcome.unwrap(), want, "quantized path diverged");
    server.shutdown();
}

#[test]
fn deviceset_service_over_loopback_matches_direct_bitwise() {
    // The sharded serving shape (`HLGPU_DEVICES=2` in production, an
    // explicit two-member set here), driven remotely.
    let mut imgs = Vec::new();
    for i in 0..8u64 {
        imgs.push(random_phantom(10, 4200 + i));
    }
    let want = direct_features(&imgs);

    let set = DeviceSet::emulator(2).unwrap();
    let svc = Service::on_set(set, &thetas(), serve_config()).unwrap();
    let server = NetServer::bind("127.0.0.1:0", svc, NetConfig::default()).unwrap();
    let mut client = NetClient::connect(&server.addr().to_string(), "sharded").unwrap();
    let mut ids = Vec::new();
    for img in &imgs {
        ids.push(client.submit(img, DEADLINE_US).unwrap());
    }
    for (i, &id) in ids.iter().enumerate() {
        let (got_id, outcome) = client.recv().unwrap();
        assert_eq!(got_id, id);
        assert_eq!(outcome.unwrap(), want[i], "image {i} diverged through the set");
    }
    let members = server.service().device_set().unwrap().stats();
    let total: u64 = members.iter().map(|m| m.images).sum();
    assert_eq!(total, imgs.len() as u64, "every image attributed to a set member");
    server.shutdown();
}

// ------------------------------------------------------- robustness --

#[test]
fn garbage_stream_gets_typed_protocol_error_and_clean_close() {
    let server = server_on(serve_config());
    let mut raw = TcpStream::connect(server.addr()).unwrap();
    // An HTTP request's first four bytes decode as a ~542 MB frame
    // length — far past the cap.
    raw.write_all(b"GET / HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    raw.flush().unwrap();
    match wire::read_frame(&mut raw, u32::MAX).unwrap() {
        Some(Frame::Response { id: 0, outcome: Err(f) }) => {
            assert_eq!(f.code, 63, "protocol violations carry wire code 63");
            let err = f.into_error();
            assert!(matches!(err, Error::Protocol(_)), "got {err:?}");
            assert!(err.to_string().contains("oversized"), "{err}");
        }
        other => panic!("expected a typed protocol response, got {other:?}"),
    }
    // …and then a clean close, not a wedge.
    let next = wire::read_frame(&mut raw, u32::MAX).unwrap();
    assert!(next.is_none(), "clean EOF after the error, got {next:?}");
    server.shutdown();
}

#[test]
fn unknown_frame_type_after_handshake_errors_and_closes() {
    let server = server_on(serve_config());
    let mut raw = raw_handshake(server.addr(), "raw");
    // len=2 covers the type byte (0x63 — unknown) and one payload byte.
    raw.write_all(&[2, 0, 0, 0, 0x63, 0]).unwrap();
    raw.flush().unwrap();
    match wire::read_frame(&mut raw, u32::MAX).unwrap() {
        Some(Frame::Response { id: 0, outcome: Err(f) }) => {
            assert_eq!(f.code, 63);
            assert!(f.msg.contains("unknown frame type"), "{}", f.msg);
        }
        other => panic!("expected a typed protocol response, got {other:?}"),
    }
    assert!(wire::read_frame(&mut raw, u32::MAX).unwrap().is_none());
    server.shutdown();
}

#[test]
fn truncated_frame_closes_cleanly_and_the_next_connection_serves() {
    let server = server_on(serve_config());
    {
        let mut raw = raw_handshake(server.addr(), "trunc");
        // Announce a full frame, deliver half of it, hang up.
        let full = wire::encode(&Frame::Request {
            id: 1,
            deadline_us: DEADLINE_US,
            size: 10,
            pixels: Pixels::F32(random_phantom(10, 4300).pixels().to_vec()),
        });
        raw.write_all(&full[..full.len() / 2]).unwrap();
        raw.flush().unwrap();
        // Dropping `raw` closes mid-frame; the server must treat that as
        // a violation on this connection only.
    }
    let img = random_phantom(10, 4301);
    let want = direct_one(&img);
    let mut client = NetClient::connect(&server.addr().to_string(), "after").unwrap();
    let feats = client.features(&img, DEADLINE_US).unwrap();
    assert_eq!(feats, want, "a truncated neighbor must not poison the listener");
    server.shutdown();
}

#[test]
fn partial_writes_across_frame_boundaries_reassemble() {
    let server = server_on(serve_config());
    let img = random_phantom(12, 4400);
    let want = direct_one(&img);

    let mut raw = raw_handshake(server.addr(), "dribble");
    raw.set_nodelay(true).unwrap();
    // Dribble the request a few bytes at a time, with pauses straddling
    // the length header, the type byte and the payload: the server must
    // reassemble exactly one frame from many short reads.
    let full = wire::encode(&Frame::Request {
        id: 9,
        deadline_us: DEADLINE_US,
        size: 12,
        pixels: Pixels::F32(img.pixels().to_vec()),
    });
    for chunk in [&full[..2], &full[2..5], &full[5..40]] {
        raw.write_all(chunk).unwrap();
        raw.flush().unwrap();
        std::thread::sleep(Duration::from_millis(30));
    }
    raw.write_all(&full[40..]).unwrap();
    raw.flush().unwrap();
    match wire::read_frame(&mut raw, u32::MAX).unwrap() {
        Some(Frame::Response { id: 9, outcome: Ok(feats) }) => {
            assert_eq!(feats, want, "reassembled request diverged");
        }
        other => panic!("expected the served response, got {other:?}"),
    }
    wire::write_frame(&mut raw, &Frame::Goodbye).unwrap();
    server.shutdown();
}

#[test]
fn client_disconnect_mid_batch_still_resolves_server_tickets() {
    // A long flush delay guarantees the requests are still queued —
    // tickets unresolved, responses unwritten — when the client hangs
    // up. Nothing may leak: every admitted ticket must still reach a
    // terminal outcome and the books must balance.
    let server = server_on(ServeConfig {
        max_batch: 64,
        max_delay_us: 100_000,
        workers: 1,
        ..serve_config()
    });
    let service = server.service().clone();
    {
        let mut client = NetClient::connect(&server.addr().to_string(), "ghost").unwrap();
        for i in 0..4u64 {
            client.submit(&random_phantom(10, 4500 + i), DEADLINE_US).unwrap();
        }
        // Dropped without recv or GOODBYE: an abrupt disconnect with
        // four tickets in flight.
    }
    // Shutdown waits out the writers and drains the service; afterwards
    // every ticket has resolved.
    server.shutdown();
    let st = service.stats("ghost");
    assert_eq!(st.admitted, 4, "all four requests were admitted before the hangup");
    let resolved = st.served + st.expired + st.failed;
    assert_eq!(resolved, st.admitted, "every ticket reached a terminal outcome: {st:?}");
    assert_eq!(st.rejected, 0, "{st:?}");
}

#[test]
fn server_shutdown_drains_inflight_responses_to_the_client() {
    // Requests parked on a long age trigger; shutdown must flush them
    // through the workers AND deliver every response before the socket
    // closes (writers drain while the service is still alive).
    let server = server_on(ServeConfig {
        max_batch: 64,
        max_delay_us: 100_000,
        workers: 1,
        ..serve_config()
    });
    let mut imgs = Vec::new();
    for i in 0..3u64 {
        imgs.push(random_phantom(10, 4600 + i));
    }
    let want = direct_features(&imgs);
    let client = NetClient::connect(&server.addr().to_string(), "drain").unwrap();
    let (mut tx, mut rx) = client.split();
    let mut ids = Vec::new();
    for img in &imgs {
        ids.push(tx.submit(img, DEADLINE_US).unwrap());
    }
    let shutter = std::thread::spawn(move || server.shutdown());
    for (i, &id) in ids.iter().enumerate() {
        match rx.recv().unwrap() {
            Some(Received::Response(got_id, outcome)) => {
                assert_eq!(got_id, id);
                assert_eq!(outcome.unwrap(), want[i], "drained response {i} diverged");
            }
            Some(Received::Stats(..)) => panic!("unexpected stats reply for response {i}"),
            None => panic!("server closed before delivering response {i}"),
        }
    }
    assert!(rx.recv().unwrap().is_none(), "clean EOF after the drain");
    shutter.join().unwrap();
}

#[test]
fn stats_probe_returns_the_serving_snapshot() {
    // Far synthesized ordinals: exact health/counter assertions must not
    // collide with an ambient chaos schedule on the real device table.
    let base = device_count() + 820;
    let members = [Device::emulator_at(base, None), Device::emulator_at(base + 1, None)];
    let set = DeviceSet::new(&members).unwrap();
    let svc = Service::on_set(set, &thetas(), serve_config()).unwrap();
    let server = NetServer::bind("127.0.0.1:0", svc, NetConfig::default()).unwrap();
    let mut client = NetClient::connect(&server.addr().to_string(), "probe").unwrap();
    for i in 0..3u64 {
        let feats = client.features(&random_phantom(10, 4700 + i), DEADLINE_US).unwrap();
        assert!(!feats.is_empty());
    }
    let snap = client.stats().unwrap();
    assert_eq!(snap.get("queue_depth").unwrap().as_usize(), Some(0));
    let probe = snap.get("tenants").unwrap().get("probe").unwrap();
    assert_eq!(probe.get("admitted").unwrap().as_usize(), Some(3));
    assert_eq!(probe.get("served").unwrap().as_usize(), Some(3));
    assert_eq!(probe.get("failed").unwrap().as_usize(), Some(0));
    assert!(probe.get("batches").unwrap().as_obj().is_some());
    let devices = snap.get("devices").unwrap().as_arr().unwrap();
    assert_eq!(devices.len(), 2, "one snapshot entry per set member");
    for d in devices {
        assert_eq!(d.get("health").unwrap().as_str(), Some("healthy"));
        assert!(d.get("ordinal").unwrap().as_usize().unwrap() >= base);
    }
    let config = snap.get("config").unwrap();
    assert_eq!(config.get("queue_capacity").unwrap().as_usize(), Some(64));
    client.goodbye().unwrap();
    server.shutdown();
}

// ---------------------------------------------- injected device loss --

static CHAOS_LOCK: Mutex<()> = Mutex::new(());

/// Exclusive, self-cleaning access to the process-global fault plane
/// (same idiom as `rust/tests/faults.rs`).
struct Chaos {
    _guard: std::sync::MutexGuard<'static, ()>,
}

impl Chaos {
    fn begin() -> Chaos {
        let guard = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        faults::reset_all();
        Chaos { _guard: guard }
    }
}

impl Drop for Chaos {
    fn drop(&mut self) {
        faults::reset_all();
    }
}

#[test]
fn injected_device_loss_is_invisible_to_the_remote_client() {
    let _chaos = Chaos::begin();
    // Far ordinals: the injected loss must not leak into parallel tests.
    let base = device_count() + 840;
    let mut imgs = Vec::new();
    for i in 0..8u64 {
        imgs.push(random_phantom(10, 4800 + i));
    }
    let want = direct_features(&imgs);

    let members = [Device::emulator_at(base, None), Device::emulator_at(base + 1, None)];
    let set = DeviceSet::new(&members).unwrap();
    let ord0 = set.device(0).ordinal;
    // The single worker pins onto member 0; its first launch kills it.
    faults::install(FaultPlan::new().fail(FaultSite::Launch, ord0, 1));
    let config = ServeConfig { max_batch: 4, max_delay_us: 1_000, workers: 1, ..serve_config() };
    let svc = Service::on_set(set.clone(), &thetas(), config).unwrap();
    let server = NetServer::bind("127.0.0.1:0", svc, NetConfig::default()).unwrap();
    let mut client = NetClient::connect(&server.addr().to_string(), "remote").unwrap();
    let mut ids = Vec::new();
    for img in &imgs {
        ids.push(client.submit(img, DEADLINE_US).unwrap());
    }
    for (i, &id) in ids.iter().enumerate() {
        let (got_id, outcome) = client.recv().unwrap();
        assert_eq!(got_id, id);
        // The loss, the re-admission and the worker re-pin all happen
        // behind the admission queue: the client sees only correct
        // features, bitwise identical to the fault-free direct run.
        assert_eq!(outcome.unwrap(), want[i], "image {i} diverged under failover");
    }
    // The detour IS visible where it should be: the stats snapshot.
    let snap = client.stats().unwrap();
    let remote = snap.get("tenants").unwrap().get("remote").unwrap();
    assert_eq!(remote.get("served").unwrap().as_usize(), Some(8));
    assert!(remote.get("retried").unwrap().as_usize().unwrap() >= 1, "re-admission recorded");
    assert!(remote.get("failed_over").unwrap().as_usize().unwrap() >= 1, "re-pin recorded");
    let devices = snap.get("devices").unwrap().as_arr().unwrap();
    let lost = devices
        .iter()
        .find(|d| d.get("ordinal").unwrap().as_usize() == Some(ord0))
        .expect("the killed member is in the snapshot");
    assert_eq!(lost.get("health").unwrap().as_str(), Some("lost"));
    assert_eq!(set.health(0), Health::Lost);
    assert_eq!(faults::injections(FaultSite::Launch, ord0), 1, "exactly one injection fired");
    client.goodbye().unwrap();
    server.shutdown();
}

// ------------------------------------------------------ typed errors --

#[test]
fn failure_frames_reconstruct_typed_errors_end_to_end() {
    // Shed and expired admissions cross the wire as the same typed
    // variants an in-process caller matches on.
    let server = server_on(ServeConfig {
        max_batch: 64,
        max_delay_us: 1_000_000,
        queue_capacity: 2,
        workers: 1,
        ..serve_config()
    });
    let mut client = NetClient::connect(&server.addr().to_string(), "typed").unwrap();
    // Zero budget: refused at admission with the typed deadline error.
    let id = client.submit(&random_phantom(10, 4900), 0).unwrap();
    let (got_id, outcome) = client.recv().unwrap();
    assert_eq!(got_id, id);
    match outcome.unwrap_err() {
        Error::DeadlineExceeded { waited_us: 0, budget_us: 0 } => {}
        other => panic!("expected the typed deadline rejection, got {other:?}"),
    }
    // Fill the 2-slot queue, then overflow it: exactly one of the three
    // pipelined submissions comes back Overloaded with the queue's
    // numbers (the 1 s flush delay keeps the first two queued).
    let mut ids = Vec::new();
    for i in 0..3u64 {
        ids.push(client.submit(&random_phantom(10, 4910 + i), DEADLINE_US).unwrap());
    }
    let mut outcomes = Vec::new();
    for &id in &ids {
        let (got_id, outcome) = client.recv().unwrap();
        assert_eq!(got_id, id);
        outcomes.push(outcome);
    }
    let shed: Vec<&Error> = outcomes.iter().filter_map(|o| o.as_ref().err()).collect();
    assert_eq!(shed.len(), 1, "exactly one of three overflowed the 2-slot queue");
    let is_overloaded = matches!(shed[0], Error::Overloaded { capacity: 2, .. });
    assert!(is_overloaded, "got {:?}", shed[0]);
    assert_eq!(outcomes.iter().filter(|o| o.is_ok()).count(), 2);
    server.shutdown();
}

#[test]
fn version_mismatch_is_refused_with_a_typed_error() {
    let server = server_on(serve_config());
    let mut raw = TcpStream::connect(server.addr()).unwrap();
    let hello = Frame::Hello { version: VERSION + 1, tenant: "v2".to_string() };
    wire::write_frame(&mut raw, &hello).unwrap();
    raw.flush().unwrap();
    match wire::read_frame(&mut raw, u32::MAX).unwrap() {
        Some(Frame::Response { id: 0, outcome: Err(WireFailure { code: 63, msg, .. }) }) => {
            assert!(msg.contains("version"), "{msg}");
        }
        other => panic!("expected a version refusal, got {other:?}"),
    }
    assert!(wire::read_frame(&mut raw, u32::MAX).unwrap().is_none());
    // A matching-version client still connects.
    let client = NetClient::connect(&server.addr().to_string(), "ok");
    assert!(client.is_ok(), "{:?}", client.err());
    server.shutdown();
}
