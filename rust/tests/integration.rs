//! Integration tests across the driver, runtime, emulator and coordinator
//! layers — the full module→function→launch lifecycle on both backends,
//! plus artifact-manifest round trips against the real `artifacts/` dir.
//!
//! PJRT-dependent tests skip gracefully when `make artifacts` has not run.

use hlgpu::coordinator::{arg, Launcher, TransferPolicy};
use hlgpu::cuda;
use hlgpu::driver::{Context, Event, KernelArg, LaunchConfig, ModuleSource};
use hlgpu::emulator::kernels;
use hlgpu::runtime::ArtifactLibrary;
use hlgpu::tensor::Tensor;
use hlgpu::tracetransform::{impls, orientations, shepp_logan, DeviceChoice};

fn have_artifacts() -> bool {
    ArtifactLibrary::load_default().is_ok()
}

// ---------------------------------------------------------------- driver --

#[test]
fn driver_full_lifecycle_on_emulator() {
    let dev = hlgpu::driver::emulator_device().unwrap();
    let ctx = Context::create(&dev).unwrap();
    let module = ctx
        .load_module(&ModuleSource::Vtx { kernels: vec![kernels::vadd().unwrap()] })
        .unwrap();
    let f = module.function("vadd").unwrap();

    let n = 1000usize;
    let bytes = |v: &[f32]| -> Vec<u8> { v.iter().flat_map(|x| x.to_le_bytes()).collect() };
    let a = ctx.alloc_upload(&bytes(&vec![1.5f32; n])).unwrap();
    let b = ctx.alloc_upload(&bytes(&vec![2.5f32; n])).unwrap();
    let c = ctx.alloc(n * 4).unwrap();
    f.launch(
        &LaunchConfig::new(((n + 255) / 256) as u32, 256u32),
        &[
            KernelArg::Ptr(a),
            KernelArg::Ptr(b),
            KernelArg::Ptr(c),
            KernelArg::I32(n as i32),
        ],
        ctx.memory().unwrap(),
    )
    .unwrap();
    let mut out = vec![0u8; n * 4];
    ctx.download(c, &mut out).unwrap();
    assert!(out
        .chunks_exact(4)
        .map(|ch| f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]))
        .all(|v| v == 4.0));

    // module cache: same source name returns the cached module
    let again = ctx
        .load_module(&ModuleSource::Vtx { kernels: vec![kernels::vadd().unwrap()] })
        .unwrap();
    assert_eq!(again.name(), module.name());
    assert_eq!(ctx.loaded_modules().len(), 1);
}

#[test]
fn streams_order_launches_and_events_time_them() {
    let dev = hlgpu::driver::emulator_device().unwrap();
    let ctx = Context::create(&dev).unwrap();
    let module = ctx
        .load_module(&ModuleSource::Vtx { kernels: vec![kernels::vadd().unwrap()] })
        .unwrap();
    let f = module.function("vadd").unwrap();
    let stream = ctx.create_stream().unwrap();

    let n = 64usize;
    let ones = vec![1.0f32; n];
    let bytes: Vec<u8> = ones.iter().flat_map(|x| x.to_le_bytes()).collect();
    let a = ctx.alloc_upload(&bytes).unwrap();
    let b = ctx.alloc_upload(&bytes).unwrap();
    let c = ctx.alloc(n * 4).unwrap();

    let begin = Event::new();
    let end = Event::new();
    begin.record_now();
    // chain k launches: c = a+b, then a = c+b, ... on one stream
    let mem = ctx.memory_arc().unwrap();
    for i in 0..8 {
        let f = f.clone();
        let mem = mem.clone();
        let (x, y, z) = if i % 2 == 0 { (a, b, c) } else { (c, b, a) };
        stream
            .enqueue(move || {
                f.launch(
                    &LaunchConfig::new(1u32, n as u32),
                    &[
                        KernelArg::Ptr(x),
                        KernelArg::Ptr(y),
                        KernelArg::Ptr(z),
                        KernelArg::I32(n as i32),
                    ],
                    &mem,
                )
            })
            .unwrap();
    }
    stream.record_event(&end).unwrap();
    stream.synchronize().unwrap();
    assert!(Event::elapsed_ms(&begin, &end).unwrap() >= 0.0);

    // after 8 chained adds starting from (1,1): a = 1+8*1 = 9
    let mut out = vec![0u8; n * 4];
    ctx.download(a, &mut out).unwrap();
    let v = f32::from_le_bytes([out[0], out[1], out[2], out[3]]);
    assert_eq!(v, 9.0);
}

// ---------------------------------------------------------------- runtime --

#[test]
fn manifest_round_trip_on_real_artifacts() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let lib = ArtifactLibrary::load_default().unwrap();
    assert!(lib.len() >= 30, "expected a full artifact set, got {}", lib.len());
    // every artifact file exists and parses at least as non-empty text
    for e in lib.entries() {
        let path = lib.artifact_path(e);
        let meta = std::fs::metadata(&path).unwrap_or_else(|_| panic!("missing {path:?}"));
        assert!(meta.len() > 100, "{path:?} suspiciously small");
        assert!(!e.inputs.is_empty());
        assert!(!e.outputs.is_empty());
    }
    // signature lookups for the kernels the implementations rely on
    for s in [16usize, 32, 64, 128, 256] {
        let sig = format!("f32[{s},{s}];f32[90]");
        assert!(lib.find("sinogram_all", &sig).is_ok(), "missing sinogram_all {s}");
    }
}

#[test]
fn pjrt_artifact_executes_with_correct_numerics() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let lib = ArtifactLibrary::load_default().unwrap();
    let ctx = Context::default_device().unwrap();
    let entry = lib.find("vadd", "f32[12];f32[12]").unwrap();
    let module = ctx.load_module(&lib.module_source(entry)).unwrap();
    let f = module.function("main").unwrap();

    let a: Vec<f32> = (0..12).map(|i| i as f32).collect();
    let b: Vec<f32> = (0..12).map(|i| (i * 10) as f32).collect();
    let bytes = |v: &[f32]| -> Vec<u8> { v.iter().flat_map(|x| x.to_le_bytes()).collect() };
    let ga = ctx.alloc_upload(&bytes(&a)).unwrap();
    let gb = ctx.alloc_upload(&bytes(&b)).unwrap();
    let gc = ctx.alloc(12 * 4).unwrap();
    f.launch(
        &LaunchConfig::new(12u32, 1u32),
        &[KernelArg::Ptr(ga), KernelArg::Ptr(gb), KernelArg::Ptr(gc)],
        ctx.memory().unwrap(),
    )
    .unwrap();
    let mut out = vec![0u8; 48];
    ctx.download(gc, &mut out).unwrap();
    let got: Vec<f32> = out
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let want: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
    assert_eq!(got, want);
}

// ------------------------------------------------------------ coordinator --

#[test]
fn automation_full_path_on_pjrt() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let mut launcher = Launcher::with_default_context().unwrap();
    let n = 1024usize;
    let a = Tensor::from_f32(&vec![2.0; n], &[n]);
    let b = Tensor::from_f32(&vec![3.0; n], &[n]);
    let mut c = Tensor::zeros_f32(&[n]);
    for _ in 0..3 {
        cuda!(launcher, (n, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)))
            .unwrap();
    }
    assert!(c.as_f32().iter().all(|&v| v == 5.0));
    let stats = launcher.cache_stats();
    assert_eq!(stats.misses, 1);
    assert_eq!(stats.hits, 2);
}

#[test]
fn transfer_counters_match_plan_on_pjrt() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let mut launcher = Launcher::with_default_context().unwrap();
    let n = 1024usize;
    let a = Tensor::from_f32(&vec![1.0; n], &[n]);
    let b = Tensor::from_f32(&vec![1.0; n], &[n]);
    let mut c = Tensor::zeros_f32(&[n]);
    // warm up
    cuda!(launcher, (n, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)))
        .unwrap();
    launcher.context().memory().unwrap().reset_stats();
    cuda!(launcher, (n, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)))
        .unwrap();
    let st = launcher.context().mem_stats().unwrap();
    assert_eq!(st.h2d_count, 2, "two CuIn uploads");
    assert_eq!(st.d2h_count, 1, "one CuOut download");
    assert_eq!(st.alloc_count, 0, "warm launch allocates nothing");

    // naive policy moves more
    launcher.set_policy(TransferPolicy::Naive);
    launcher.context().memory().unwrap().reset_stats();
    cuda!(launcher, (n, 1), vadd(arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)))
        .unwrap();
    let st = launcher.context().mem_stats().unwrap();
    assert_eq!(st.h2d_count, 3);
    assert_eq!(st.d2h_count, 3);
}

#[test]
fn cross_backend_same_call_agrees() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let size = 32usize;
    let angles = 90usize;
    let img = shepp_logan(size).to_tensor();
    let thetas = orientations(angles);
    let ang = Tensor::from_f32(&thetas, &[angles]);
    let cfg = LaunchConfig::new(angles as u32, size as u32);

    let mut on_pjrt = Tensor::zeros_f32(&[4, angles, size]);
    let mut launcher = Launcher::with_default_context().unwrap();
    launcher
        .launch(
            "sinogram_all",
            cfg,
            &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut on_pjrt)],
        )
        .unwrap();

    let mut on_emu = Tensor::zeros_f32(&[4, angles, size]);
    let mut launcher = Launcher::emulator().unwrap();
    impls::register_trace_providers(launcher.registry_mut());
    launcher
        .launch(
            "sinogram_all",
            cfg,
            &mut [arg::cu_in(&img), arg::cu_in(&ang), arg::cu_out(&mut on_emu)],
        )
        .unwrap();

    for (i, (x, y)) in on_pjrt.as_f32().iter().zip(on_emu.as_f32()).enumerate() {
        assert!(
            (x - y).abs() < 1e-2 * x.abs().max(1.0),
            "element {i}: pjrt {x} vs emu {y}"
        );
    }
}

#[test]
fn auto_arguments_inferred_from_artifact_split_on_pjrt() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    // No wrappers: the framework matches the call positionally against
    // the artifact's inputs ++ outputs and derives the transfer plan.
    let mut launcher = Launcher::with_default_context().unwrap();
    let n = 1024usize;
    let mut a = Tensor::from_f32(&vec![4.0; n], &[n]);
    let mut b = Tensor::from_f32(&vec![5.0; n], &[n]);
    let mut c = Tensor::zeros_f32(&[n]);
    launcher
        .launch(
            "vadd",
            LaunchConfig::new(n as u32, 1u32),
            &mut [arg::cu_auto(&mut a), arg::cu_auto(&mut b), arg::cu_auto(&mut c)],
        )
        .unwrap();
    assert!(c.as_f32().iter().all(|&v| v == 9.0));
    launcher.context().memory().unwrap().reset_stats();
    launcher
        .launch(
            "vadd",
            LaunchConfig::new(n as u32, 1u32),
            &mut [arg::cu_auto(&mut a), arg::cu_auto(&mut b), arg::cu_auto(&mut c)],
        )
        .unwrap();
    let st = launcher.context().mem_stats().unwrap();
    assert_eq!((st.h2d_count, st.d2h_count), (2, 1), "inferred minimal plan");
}

#[test]
fn wrong_output_shape_fails_specialization() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let mut launcher = Launcher::with_default_context().unwrap();
    let n = 1024usize;
    let a = Tensor::from_f32(&vec![1.0; n], &[n]);
    let b = Tensor::from_f32(&vec![1.0; n], &[n]);
    let mut c = Tensor::zeros_f32(&[n + 1]); // wrong!
    let err = launcher
        .launch(
            "vadd",
            LaunchConfig::new(n as u32, 1u32),
            &mut [arg::cu_in(&a), arg::cu_in(&b), arg::cu_out(&mut c)],
        )
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("output") || msg.contains("f32[1025]"), "{msg}");
}

#[test]
fn manual_path_recycles_pool_bins_between_calls() {
    // The Listing-2 manual flow allocs/frees ga/gb/gc every call; with the
    // caching allocator the second call must be served entirely from the
    // pool's bins (no fresh host allocations on the steady-state path).
    use hlgpu::driver::PoolPolicy;
    use hlgpu::tracetransform::TraceImpl;
    let img = shepp_logan(12);
    let thetas = orientations(6);
    let mut m = impls::GpuManual::on_device(DeviceChoice::Emulator).unwrap();
    m.features(&img, &thetas).unwrap();
    m.context().memory().unwrap().reset_stats();
    m.features(&img, &thetas).unwrap();
    let st = m.context().mem_stats().unwrap();
    assert_eq!(st.alloc_count, 3, "ga/gb/gc per call");
    match m.context().memory().unwrap().policy() {
        PoolPolicy::Cached => {
            assert_eq!(st.reuse_count, 3, "warm call fully served from bins");
            assert!((st.pool_hit_rate() - 1.0).abs() < 1e-9);
        }
        PoolPolicy::Uncached => {
            assert_eq!(st.reuse_count, 0, "HLGPU_POOL=none never recycles");
        }
    }
    // all device memory released either way
    assert_eq!(m.context().memory().unwrap().live_buffers(), 0);
}

#[test]
fn batch_and_sequential_agree_through_the_automation_layer() {
    use hlgpu::tracetransform::{GpuAuto, TraceImpl};
    let imgs: Vec<_> = (0..3)
        .map(|i| hlgpu::tracetransform::random_phantom(14, 90 + i as u64))
        .collect();
    let thetas = orientations(8);
    let mut auto = GpuAuto::on_device(DeviceChoice::Emulator).unwrap();
    let batch = auto.features_batch(&imgs, &thetas).unwrap();
    for (i, img) in imgs.iter().enumerate() {
        let seq = auto.features(img, &thetas).unwrap();
        for (j, (x, y)) in batch[i].iter().zip(&seq).enumerate() {
            assert!(
                (x - y).abs() < 1e-4 * x.abs().max(1.0),
                "image {i} feature {j}: {x} vs {y}"
            );
        }
    }
}

// ------------------------------------------------------------- e2e sanity --

#[test]
fn trace_pipeline_e2e_small() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    use hlgpu::tracetransform::{CpuNative, GpuAuto, TraceImpl};
    let img = shepp_logan(16);
    let thetas = orientations(90);
    let want = CpuNative::new().features(&img, &thetas).unwrap();
    let got = GpuAuto::on_device(DeviceChoice::Pjrt)
        .unwrap()
        .features(&img, &thetas)
        .unwrap();
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 2e-3 * w.abs().max(1.0), "feature {i}: {g} vs {w}");
    }
}
